//! The unified streaming engine — the long-lived execution core behind
//! both `cugwas run` and `cugwas serve`.
//!
//! The paper's sustained-peak result comes from keeping ONE pipeline
//! saturated end to end. The old coordinator tore that pipeline down and
//! rebuilt it at every adaptive segment boundary and for every queued
//! job; this module owns the expensive resources with an explicit
//! lifecycle instead:
//!
//! ```text
//! Engine::open(cfg)          preprocess, aio reader, lane/pool slots
//!   ├─ execute(cfg)          one full run: segments + adaptation
//!   ├─ execute(cfg)          … next job on the same dataset: the
//!   │                        preprocess, reader, lanes and pools are
//!   │                        still warm (serve's back-to-back reuse)
//!   └─ execute_plans(cfg,…)  explicit segment schedule (tests/benches)
//! ```
//!
//! Between segments only the resources a [`SegmentPlan`] actually
//! changes are resized: native lanes are block-size-agnostic, so a block
//! switch re-rings the buffer pools but keeps the lane threads (and
//! their warmed kernel workers) alive; a lane-thread or channel-depth
//! switch respawns lanes but keeps the pools; and a boundary that
//! changes nothing reuses everything. The in-flight re-planner
//! ([`crate::tune::replan_knobs`]) now moves the full knob depth the
//! offline planner searches — block size, host/device buffer counts and
//! the lane-vs-S-loop thread split — with the DES pricing every
//! candidate switch *including* its transition cost
//! ([`crate::devsim::transition_secs`]).

pub mod segment;

use crate::coordinator::journal::{self, Journal};
use crate::coordinator::lane::{Backend, DeviceLane, OffloadMode};
use crate::coordinator::metrics::{Metrics, Phase};
use crate::coordinator::pipeline::{validate, BackendKind, PipelineConfig, PipelineReport};
use crate::coordinator::pool::BufPool;
use crate::devsim::{sloop_flops, trsm_flops, SegmentKnobs};
use crate::error::{Error, Result};
use crate::gwas::preprocess::{phenotype_batch, preprocess_multi, Preprocessed};
use crate::gwas::problem::Dims;
use crate::gwas::sloop::SloopScratch;
use crate::runtime::{ArtifactEntry, ArtifactKey, Kind, Manifest};
use crate::storage::fault;
use crate::storage::{
    dataset, AioEngine, AioHandle, AioStats, BlockCache, Header, ReadProbe, SlabPool, Throttle,
    XrdFile,
};
use crate::telemetry::{self, StallVerdict};
use crate::tune::{fit_disk_latency, replan_knobs, LiveObs};
use crate::util::threads;
use segment::{run_segment, take_windows, SegmentCtx};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub use segment::SegmentPlan;

/// Cumulative resource accounting of one engine — the observable proof
/// of reuse (`tests/engine_adaptive.rs` asserts on it).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    /// Times the device-lane set was (re)spawned.
    pub lane_builds: u64,
    /// Times the buffer rings were (re)allocated.
    pub pool_builds: u64,
    /// Completed `execute`/`execute_plans` calls.
    pub runs: u64,
}

/// What the current lane set was built for; a segment whose knobs hash
/// to the same key keeps the lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct LaneKey {
    ngpus: usize,
    lane_threads: usize,
    device_buffers: usize,
    /// PJRT artifacts bake the chunk width in; native lanes are
    /// block-size-agnostic (keyed as 0).
    mb_gpu: usize,
}

/// What the current buffer rings were built for. On the zero-copy plane
/// the rings are the slab pool (read side) and the result ring (write
/// side) — both sized by `block × host_buffers` only: the per-lane
/// staging chunks that used to key on `device_buffers × ngpus` no longer
/// exist (lanes borrow views into the slabs), so a device-buffer or
/// lane-count switch leaves the pools untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct PoolKey {
    block: usize,
    host_buffers: usize,
}

/// Two-point live fit of the disk's per-request latency: once two
/// segments have streamed at different request sizes, their per-request
/// timings solve `t = lat + bytes/bw` — the in-flight analogue of the
/// probe's two-window measurement, reusing the same
/// [`fit_disk_latency`] solver.
#[derive(Default)]
struct DiskLatFit {
    last: Option<ReadProbe>,
    lat_secs: f64,
    /// Asymptotic bandwidth from the fit (0 = no fit yet).
    bw_mbps: f64,
}

impl DiskLatFit {
    fn update(&mut self, delta: AioStats) {
        if delta.ops == 0 {
            return;
        }
        let cur = ReadProbe { bytes: delta.bytes, secs: delta.busy.as_secs_f64(), ops: delta.ops };
        if let Some(prev) = self.last {
            let per_op = |r: &ReadProbe| r.bytes as f64 / r.ops as f64;
            let (small, big) =
                if per_op(&prev) <= per_op(&cur) { (prev, cur) } else { (cur, prev) };
            if let Some((lat, bw_bps)) = fit_disk_latency(&small, &big) {
                self.lat_secs = lat;
                self.bw_mbps = bw_bps / 1e6;
            }
        }
        self.last = Some(cur);
    }
}

/// The "link rate" the live observer reports for the zero-copy plane.
/// Staging a chunk is a reference handoff, so the link is never a
/// constraint; timing the O(1) handoff and dividing nominal bytes by it
/// would only feed the DES scheduler-preemption noise dressed up as a
/// bandwidth. A large finite constant is the honest observation (and a
/// PJRT literal boundary reports its real copy lane-side, via
/// `DevOut::staged_copy_bytes`).
const ZERO_COPY_LINK_GBPS: f64 = 1e3;

/// Phase/engine counters at a segment boundary, for live-rate deltas.
struct SegmentSnapshot {
    read_wait: Duration,
    recv_wait: Duration,
    sloop: Duration,
    device: Duration,
    reader: AioStats,
}

impl SegmentSnapshot {
    fn take(metrics: &Metrics, reader: AioStats) -> SegmentSnapshot {
        SegmentSnapshot {
            read_wait: metrics.total(Phase::ReadWait),
            recv_wait: metrics.total(Phase::RecvWait),
            sloop: metrics.total(Phase::Sloop),
            device: metrics.total(Phase::DeviceCompute),
            reader,
        }
    }

    /// Turn the counter deltas since this snapshot into live rates.
    #[allow(clippy::too_many_arguments)]
    fn observe(
        &self,
        metrics: &Metrics,
        reader: AioStats,
        wall_secs: f64,
        n: usize,
        pl: usize,
        cols: usize,
        traits: usize,
        lat: &DiskLatFit,
    ) -> LiveObs {
        let secs = |now: Duration, then: Duration| now.saturating_sub(then).as_secs_f64();
        let rate = |units: f64, secs: f64| if secs > 0.0 { units / secs } else { 0.0 };
        let device = secs(metrics.total(Phase::DeviceCompute), self.device);
        let sloop = secs(metrics.total(Phase::Sloop), self.sloop);
        let effective_mbps = reader.since(&self.reader).mbps();
        LiveObs {
            wall_secs,
            read_wait_secs: secs(metrics.total(Phase::ReadWait), self.read_wait),
            recv_wait_secs: secs(metrics.total(Phase::RecvWait), self.recv_wait),
            disk_mbps: if lat.bw_mbps > 0.0 { lat.bw_mbps } else { effective_mbps },
            disk_lat_secs: lat.lat_secs,
            trsm_gflops: rate(trsm_flops(n, cols), device) / 1e9,
            cpu_gflops: rate(sloop_flops(n, pl, cols, traits), sloop) / 1e9,
            pcie_gbps: ZERO_COPY_LINK_GBPS,
        }
    }
}

/// The long-lived streaming engine (see module docs).
pub struct Engine {
    // ---- identity: what this engine was opened for ---------------------
    dataset: PathBuf,
    canonical: PathBuf,
    mode: OffloadMode,
    backend: BackendKind,
    opened_block: usize,
    opened_ngpus: usize,
    read_throttle: Option<Throttle>,
    cache: Option<Arc<BlockCache>>,
    cache_dataset: Option<String>,
    total_threads: usize,
    /// Trait-batch width the phenotype matrix was built for. Part of the
    /// engine identity: the preprocess, the result geometry (`p·t` rows)
    /// and the journal header all depend on it.
    traits: usize,
    /// Seed behind the shuffled phenotype columns (`traits > 1`).
    perm_seed: u64,
    // ---- long-lived resources ------------------------------------------
    meta: dataset::Meta,
    /// Shared with every device lane (read-only after preprocess).
    pre: Arc<Preprocessed>,
    backend_proto: Option<ArtifactEntry>,
    reader: AioEngine,
    lanes: Vec<DeviceLane>,
    lane_key: Option<LaneKey>,
    /// Aligned slab ring the reads land in (blocks flow out of it by
    /// reference — see [`crate::storage::slab`]).
    slabs: SlabPool,
    result_pool: BufPool,
    pool_key: Option<PoolKey>,
    scratch: SloopScratch,
    stats: EngineStats,
}

impl Engine {
    /// Open an engine for `cfg`'s dataset: load the sidecars, run the
    /// preprocessing (Listing 1.3 lines 1–7, with the full thread
    /// budget — the lanes don't exist yet), and spin up the aio reader.
    /// Lanes and pools are built lazily by the first segment.
    pub fn open(cfg: &PipelineConfig) -> Result<Engine> {
        validate(cfg)?;
        let (meta, kin, xl, y) = dataset::load_sidecars(&cfg.dataset)?;
        let dims = meta.dims;
        let mb_gpu = cfg.block / cfg.ngpus;

        // Resolve backend + the diagonal block size for preprocessing.
        let (backend_proto, dinv_nb) = match &cfg.backend {
            BackendKind::Native => (None, 0),
            BackendKind::Pjrt { artifacts } => {
                let manifest = Manifest::load(artifacts)?;
                let kind = match cfg.mode {
                    OffloadMode::Trsm => Kind::Trsm,
                    OffloadMode::Block => Kind::Block,
                    OffloadMode::BlockFull => Kind::BlockFull,
                };
                let entry = manifest
                    .get(&ArtifactKey { kind, n: dims.n, pl: dims.pl, mb: mb_gpu })?
                    .clone();
                let nb = entry.nb;
                (Some(entry), nb)
            }
        };

        let total = if cfg.threads == 0 { threads::available() } else { cfg.threads };
        let pre: Arc<Preprocessed> = {
            let _full = threads::with_budget(total);
            // The phenotype matrix: column 0 is y, columns 1.. are its
            // seeded permutations — one preprocess serves all of them.
            let ys = phenotype_batch(&y, cfg.traits.max(1), cfg.perm_seed);
            Arc::new(preprocess_multi(&kin, &xl, &ys, dinv_nb)?)
        };

        let paths = dataset::DatasetPaths::new(&cfg.dataset);
        let xr = XrdFile::open(&paths.xr())?.with_throttle(cfg.read_throttle);
        let reader = AioEngine::new(xr);
        let canonical = dataset::canonical_key(&cfg.dataset);
        let cache_dataset = cfg.cache.as_ref().map(|_| canonical.to_string_lossy().into_owned());

        Ok(Engine {
            dataset: cfg.dataset.clone(),
            canonical,
            mode: cfg.mode,
            backend: cfg.backend.clone(),
            opened_block: cfg.block,
            opened_ngpus: cfg.ngpus,
            read_throttle: cfg.read_throttle,
            cache: cfg.cache.clone(),
            cache_dataset,
            total_threads: total,
            traits: cfg.traits.max(1),
            perm_seed: cfg.perm_seed,
            meta,
            pre,
            backend_proto,
            reader,
            lanes: Vec::new(),
            lane_key: None,
            slabs: SlabPool::new(0, 0),
            result_pool: BufPool::new(0, 0),
            pool_key: None,
            scratch: SloopScratch::new(dims.pl),
            stats: EngineStats::default(),
        })
    }

    /// Cumulative resource accounting.
    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// Dataset dimensions the engine was opened on.
    pub fn dims(&self) -> Dims {
        self.meta.dims
    }

    /// Can this engine serve `cfg` without rebuilding its long-lived
    /// resources? Same dataset (canonical identity), same offload mode,
    /// same backend (PJRT additionally pins block/lanes — the artifact
    /// and `Dinv` geometry bake the chunk width in), same resolved
    /// thread budget, same read throttle and same shared cache. The
    /// service's worker lanes use this to decide whether a back-to-back
    /// job can ride the warm engine.
    pub fn compatible(&self, cfg: &PipelineConfig) -> bool {
        let total = if cfg.threads == 0 { threads::available() } else { cfg.threads };
        let backend_ok = match (&self.backend, &cfg.backend) {
            (BackendKind::Native, BackendKind::Native) => true,
            (BackendKind::Pjrt { artifacts: a }, BackendKind::Pjrt { artifacts: b }) => {
                // The artifact entry and `Dinv` geometry were resolved
                // for the opening chunk width (block / ngpus) — both
                // knobs must match or the cached entry is wrong.
                a == b && cfg.block == self.opened_block && cfg.ngpus == self.opened_ngpus
            }
            _ => false,
        };
        let throttle_ok = match (self.read_throttle, cfg.read_throttle) {
            (None, None) => true,
            (Some(a), Some(b)) => a.bytes_per_sec == b.bytes_per_sec,
            _ => false,
        };
        let cache_ok = match (&self.cache, &cfg.cache) {
            (None, None) => true,
            (Some(a), Some(b)) => Arc::ptr_eq(a, b),
            _ => false,
        };
        backend_ok
            && throttle_ok
            && cache_ok
            && self.mode == cfg.mode
            && self.total_threads == total
            // Trait width changes the preprocess AND the result geometry;
            // a different perm seed changes the phenotype columns.
            && self.traits == cfg.traits.max(1)
            && self.perm_seed == cfg.perm_seed
            && self.canonical == dataset::canonical_key(&cfg.dataset)
    }

    /// Run one full study through the engine: stream every uncovered
    /// column window, adapting the knobs at segment boundaries when
    /// `cfg.adapt` is on. Repeated calls reuse the warm resources.
    pub fn execute(&mut self, cfg: &PipelineConfig) -> Result<PipelineReport> {
        self.run_with(cfg, None)
    }

    /// Run with an explicit segment schedule: each plan streams its
    /// window count under its knobs, and any remainder streams under the
    /// last plan's knobs (plus adaptation if `cfg.adapt`). This is the
    /// determinism suite's lever for forcing mid-stream switches at
    /// exact boundaries.
    pub fn execute_plans(
        &mut self,
        cfg: &PipelineConfig,
        plans: &[SegmentPlan],
    ) -> Result<PipelineReport> {
        self.run_with(cfg, Some(plans))
    }

    fn run_with(
        &mut self,
        cfg: &PipelineConfig,
        plans: Option<&[SegmentPlan]>,
    ) -> Result<PipelineReport> {
        let out = self.run_inner(cfg, plans);
        if out.is_err() {
            // A failed run can leave lanes holding chunks and pools short
            // of buffers; tear the streaming resources down so the next
            // run (if any) rebuilds them clean.
            self.teardown_streaming();
        }
        out
    }

    fn run_inner(
        &mut self,
        cfg: &PipelineConfig,
        plans: Option<&[SegmentPlan]>,
    ) -> Result<PipelineReport> {
        validate(cfg)?;
        if !self.compatible(cfg) {
            return Err(Error::Config(
                "engine was opened for a different dataset/backend/thread configuration \
                 — open a fresh one"
                    .into(),
            ));
        }
        let dims = self.meta.dims;
        let (n, p) = (dims.n, dims.p());
        let t = self.traits;
        if telemetry::metrics_enabled() {
            telemetry::registry::global().traits_width.set(t as f64);
        }

        // Per-run outputs: results file + journal (resume validates the
        // journal header; a mismatched results file restarts clean).
        // Result rows are `p·t`: trait k's solution stacked at rows
        // [k·p, (k+1)·p) of every column.
        let paths = dataset::DatasetPaths::new(&self.dataset);
        let r_header = Header::new(
            (p * t) as u64,
            dims.m as u64,
            cfg.block.min(dims.m) as u64,
            self.meta.seed,
        )?;
        let fresh = |paths: &dataset::DatasetPaths| -> Result<(XrdFile, Journal)> {
            let j = Journal::create(&paths.progress(), dims.m as u64, cfg.block as u64, t as u64)?;
            Ok((XrdFile::create(&paths.results(), r_header)?, j))
        };
        // Resuming with no journal on disk is a fresh start, not an
        // error: WAL replay resubmits jobs that *may* have streamed
        // (admitted, cancelled from the queue, …) with `resume` set,
        // and a job that never reached its first boundary has nothing
        // to resume from.
        let (rfile, journal, done_ranges) = if cfg.resume && paths.progress().exists() {
            let (journal, ranges) =
                Journal::open_resume(&paths.progress(), dims.m as u64, cfg.block as u64, t as u64)?;
            match XrdFile::open_rw(&paths.results()) {
                Ok(f) if *f.header() == r_header => (f, journal, ranges),
                _ => {
                    // Journaled progress points at a results file that no
                    // longer matches — recompute everything.
                    drop(journal);
                    let (f, j) = fresh(&paths)?;
                    (f, j, Vec::new())
                }
            }
        } else {
            let (f, j) = fresh(&paths)?;
            (f, j, Vec::new())
        };
        let writer = AioEngine::new(rfile.with_throttle(cfg.write_throttle));
        // Shared with the writer's I/O thread: the two-phase boundary
        // appends intents on the coordinator thread and the background
        // `sync_then` task appends the durable commit record.
        let journal = Arc::new(Mutex::new(journal));
        // The in-flight durable commit of the previous segment boundary
        // (reaped at the next boundary, or after the last segment below).
        let mut pending_commit: Option<AioHandle> = None;

        // Work list: the uncovered column ranges, streamed as windows.
        let mut remaining: VecDeque<(u64, u64)> =
            journal::uncovered(dims.m as u64, &done_ranges).into();

        let mut knobs = SegmentKnobs {
            block: cfg.block,
            host_buffers: cfg.host_buffers,
            device_buffers: cfg.device_buffers,
            lane_threads: self.resolve_lane_threads(cfg),
        };
        let mut metrics = Metrics::new();
        let mut device_secs = 0.0f64;
        let mut windows_done = 0usize;
        let mut replans = 0usize;
        let mut lat_fit = DiskLatFit::default();
        let mut plan_cursor = 0usize;
        // Lane-respawn budget for the whole run: each recovery replays
        // one segment, so the budget bounds the extra work a flapping
        // device can extort before the run fails for real.
        let mut respawns_used = 0u32;
        let t_wall = Instant::now();

        loop {
            // Segment length: the explicit schedule wins while it lasts,
            // then the adaptive cadence (or one segment for the rest).
            let seg_windows = match plans {
                Some(list) if plan_cursor < list.len() => {
                    let sp = list[plan_cursor];
                    plan_cursor += 1;
                    if sp.knobs != knobs {
                        replans += 1;
                        if telemetry::metrics_enabled() {
                            telemetry::registry::global().replans_total.add(1);
                        }
                        knobs = sp.knobs;
                    }
                    sp.windows
                }
                _ if cfg.adapt => cfg.adapt_every,
                _ => usize::MAX,
            };
            let items = take_windows(&mut remaining, knobs.block as u64, seg_windows);
            if items.is_empty() {
                if remaining.is_empty() {
                    break;
                }
                continue; // zero-window plan entry: knobs applied, no work
            }
            // Cooperative stop points, honored only here — between
            // segments — so a stopped run is always checkpoint-clean:
            // the previous boundary's durable commit is reaped first,
            // then the run returns with the journal sealed at a segment
            // edge and every committed window resumable. A run whose
            // work list just drained never stops "cancelled" — the
            // empty-items branch above breaks out before these checks.
            let stop = if cfg.shutdown.as_ref().is_some_and(|t| t.is_triggered()) {
                Some("drain requested — checkpointed at the segment boundary".to_string())
            } else if cfg.deadline_at.is_some_and(|d| Instant::now() >= d) {
                Some(format!(
                    "deadline exceeded after {:.1}s — checkpointed at the segment boundary",
                    t_wall.elapsed().as_secs_f64()
                ))
            } else {
                None
            };
            if let Some(why) = stop {
                if let Some(h) = pending_commit.take() {
                    let (_, res) = h.wait();
                    res?;
                }
                return Err(Error::Cancelled(why));
            }
            // Disk-space sentinel: a filesystem running dry mid-stream
            // fails the job *here*, at a boundary with the journal
            // consistent, naming the path — never via a torn journal
            // append or a half-written result block later.
            if cfg.disk_low_water > 0 {
                if let Some(free) = crate::util::disk_free_bytes(&self.dataset) {
                    if free < cfg.disk_low_water {
                        if let Some(h) = pending_commit.take() {
                            let (_, res) = h.wait();
                            res?;
                        }
                        return Err(Error::Pipeline(format!(
                            "free space on {} fell below the low-water mark ({} < {}) — \
                             job checkpointed at the segment boundary",
                            self.dataset.display(),
                            crate::util::human_bytes(free),
                            crate::util::human_bytes(cfg.disk_low_water),
                        )));
                    }
                }
            }
            let seg_cols: usize = items.iter().map(|&(_, live)| live).sum();
            self.ensure_resources(&knobs, cfg.ngpus)?;

            let before = SegmentSnapshot::take(&metrics, self.reader.stats());
            let t_seg = Instant::now();
            // Segment supervision: a lane that dies or wedges mid-stream
            // surfaces as [`Error::LaneFault`]. Replay is safe because a
            // failed attempt never reaches the boundary, so it appends no
            // intent records (and schedules no commit) — resume ignores
            // any intent without a covering commit anyway — result
            // writes are idempotent positioned writes, and lanes carry
            // no state across chunks. Recovery respawns the lane set and
            // re-runs the same window list, bounded by the policy's
            // respawn budget.
            loop {
                let res = {
                    // The coordinator thread keeps the S-loop's core
                    // share for this segment's split.
                    let lane_total = knobs.lane_threads * cfg.ngpus;
                    let coord = self.total_threads.saturating_sub(lane_total).max(1);
                    let _coord_budget = threads::with_budget(coord);
                    let ctx = SegmentCtx {
                        n,
                        // The segment's result-row stride: t stacked
                        // p-vectors per SNP column.
                        p: p * t,
                        mb_gpu: knobs.block / cfg.ngpus,
                        pre: self.pre.as_ref(),
                        reader: &self.reader,
                        writer: &writer,
                        cache: self.cache.as_deref(),
                        cache_dataset: self.cache_dataset.as_deref(),
                        lanes: &self.lanes,
                        slabs: &self.slabs,
                        result_pool: &mut self.result_pool,
                        scratch: &mut self.scratch,
                    };
                    run_segment(
                        ctx,
                        &items,
                        &mut metrics,
                        &journal,
                        &mut pending_commit,
                        &mut device_secs,
                    )
                };
                match res {
                    Ok(()) => break,
                    Err(Error::LaneFault { lane, msg }) => {
                        let limit = fault::policy().max_lane_respawns;
                        if respawns_used >= limit {
                            return Err(Error::LaneFault { lane, msg });
                        }
                        respawns_used += 1;
                        crate::log_info!(
                            "engine",
                            "lane {lane} fault: {msg} — respawning lanes and replaying the \
                             segment (recovery {respawns_used}/{limit})"
                        );
                        fault::note_lane_respawn();
                        // The old lanes may be dead or still waking from
                        // a wedge; drain them without letting a poisoned
                        // join abort the recovery, then rebuild lanes AND
                        // pools so the replay starts from full rings (a
                        // failed attempt can strand in-flight buffers).
                        for mut l in self.lanes.drain(..) {
                            l.close();
                            if let Err(e) = l.join() {
                                crate::log_info!("engine", "faulted lane exited with: {e}");
                            }
                        }
                        self.lane_key = None;
                        self.pool_key = None;
                        self.ensure_resources(&knobs, cfg.ngpus)?;
                    }
                    Err(e) => return Err(e),
                }
            }
            windows_done += items.len();
            lat_fit.update(self.reader.stats().since(&before.reader));

            // Per-segment stall attribution: the same phase shares the
            // re-planner reads, promoted to a verdict. Exported at every
            // boundary (with the slab circulation) so the `/metrics`
            // series tracks the live pipeline even on segments where no
            // knob switch happens.
            let seg_wall = t_seg.elapsed().as_secs_f64().max(1e-12);
            let dsec = |now: Duration, then: Duration| now.saturating_sub(then).as_secs_f64();
            let verdict = StallVerdict::from_shares(
                dsec(metrics.total(Phase::ReadWait), before.read_wait) / seg_wall,
                dsec(metrics.total(Phase::RecvWait), before.recv_wait) / seg_wall,
                dsec(metrics.total(Phase::Sloop), before.sloop) / seg_wall,
            );
            if telemetry::metrics_enabled() {
                let reg = telemetry::registry::global();
                reg.record_stall(verdict);
                reg.set_slabs(&self.slabs.stats(), self.slabs.target());
            }

            let schedule_done = plans.map_or(true, |list| plan_cursor >= list.len());
            if cfg.adapt && !remaining.is_empty() && schedule_done {
                let t0 = Instant::now();
                let obs = before.observe(
                    &metrics,
                    self.reader.stats(),
                    t_seg.elapsed().as_secs_f64(),
                    n,
                    dims.pl,
                    seg_cols,
                    t,
                    &lat_fit,
                );
                let left: u64 = remaining.iter().map(|&(_, len)| len).sum();
                let rdims = Dims::new(n, dims.pl, left as usize)?;
                let switch =
                    replan_knobs(&obs, rdims, knobs, cfg.ngpus, self.total_threads, t);
                if let Some(nk) = switch {
                    crate::log_info!(
                        "engine",
                        "adapt: block {}→{}, host {}→{}, device {}→{}, lane threads {}→{} \
                         (stall: {}; read {:.0}%, recv {:.0}%, disk {:.0} MB/s + {:.2} ms/req)",
                        knobs.block,
                        nk.block,
                        knobs.host_buffers,
                        nk.host_buffers,
                        knobs.device_buffers,
                        nk.device_buffers,
                        knobs.lane_threads,
                        nk.lane_threads,
                        verdict.render(),
                        100.0 * obs.read_wait_secs / obs.wall_secs.max(1e-12),
                        100.0 * obs.recv_wait_secs / obs.wall_secs.max(1e-12),
                        obs.disk_mbps,
                        obs.disk_lat_secs * 1e3,
                    );
                    knobs = nk;
                    replans += 1;
                    if telemetry::metrics_enabled() {
                        telemetry::registry::global().replans_total.add(1);
                    }
                }
                metrics.add(Phase::Replan, t0.elapsed());
            }
        }

        // The last segment's durable commit is still on the writer's I/O
        // thread — reap it so the run only reports success once every
        // journaled window is actually committed on disk.
        if let Some(h) = pending_commit.take() {
            let t0 = Instant::now();
            let (_, res) = h.wait();
            let waited = t0.elapsed();
            metrics.add(Phase::WriteWait, waited);
            telemetry::span(
                "journal_commit_wait",
                "coordinator",
                telemetry::trace::TID_COORD,
                t0,
                waited,
                &[],
            );
            res?;
        }

        self.stats.runs += 1;
        let wall_secs = t_wall.elapsed().as_secs_f64();
        let snps_per_sec = dims.m as f64 / wall_secs.max(1e-12);
        let stall = StallVerdict::from_metrics(&metrics, wall_secs);
        if telemetry::metrics_enabled() {
            telemetry::registry::global().job_done(
                wall_secs,
                dims.m as u64,
                windows_done as u64,
                snps_per_sec,
            );
        }
        Ok(PipelineReport {
            blocks: windows_done,
            snps: dims.m,
            wall_secs,
            snps_per_sec,
            metrics,
            device_secs,
            replans,
            stall,
        })
    }

    /// The per-lane kernel-thread share for `cfg` (explicit pin wins).
    fn resolve_lane_threads(&self, cfg: &PipelineConfig) -> usize {
        if cfg.lane_threads > 0 {
            cfg.lane_threads
        } else {
            (self.total_threads / (cfg.ngpus + 1)).max(1)
        }
    }

    /// Resize only what `knobs` actually changes: lanes survive any
    /// switch that keeps their key (for native backends that includes
    /// every block-size change), pools survive any switch that keeps the
    /// ring geometry.
    fn ensure_resources(&mut self, knobs: &SegmentKnobs, ngpus: usize) -> Result<()> {
        validate_knobs(knobs, ngpus)?;
        let dims = self.meta.dims;
        let (n, p) = (dims.n, dims.p());
        let mb_gpu = knobs.block / ngpus;
        let lane_key = LaneKey {
            ngpus,
            lane_threads: knobs.lane_threads,
            device_buffers: knobs.device_buffers,
            mb_gpu: if matches!(self.backend, BackendKind::Pjrt { .. }) { mb_gpu } else { 0 },
        };
        if self.lane_key != Some(lane_key) {
            for mut lane in self.lanes.drain(..) {
                lane.close();
                lane.join()?;
            }
            self.lanes = (0..ngpus)
                .map(|gi| {
                    let backend = match (&self.backend, &self.backend_proto) {
                        (BackendKind::Native, _) => Backend::Native,
                        (BackendKind::Pjrt { .. }, Some(entry)) => {
                            Backend::Pjrt { entry: entry.clone() }
                        }
                        _ => unreachable!("pjrt engines always hold an artifact entry"),
                    };
                    DeviceLane::spawn(
                        gi,
                        self.mode,
                        backend,
                        &self.pre,
                        mb_gpu,
                        knobs.lane_threads,
                        knobs.device_buffers,
                    )
                })
                .collect::<Result<_>>()?;
            self.lane_key = Some(lane_key);
            self.stats.lane_builds += 1;
        }
        let pool_key = PoolKey { block: knobs.block, host_buffers: knobs.host_buffers };
        if self.pool_key != Some(pool_key) {
            self.slabs = SlabPool::new(knobs.host_buffers, n * knobs.block);
            // Result buffers hold t stacked p-vectors per column.
            self.result_pool = BufPool::new(knobs.host_buffers, p * self.traits * knobs.block);
            self.pool_key = Some(pool_key);
            self.stats.pool_builds += 1;
        }
        Ok(())
    }

    /// Drop lanes and pools (joining the lane threads). The next run
    /// rebuilds them; the preprocess and reader stay warm.
    fn teardown_streaming(&mut self) {
        for mut lane in self.lanes.drain(..) {
            lane.close();
            let _ = lane.join();
        }
        self.lane_key = None;
        self.pool_key = None;
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.teardown_streaming();
    }
}

/// The pipeline invariants every segment must satisfy. The adaptive
/// re-planner's neighborhood enforces these by construction; an explicit
/// [`SegmentPlan`] schedule comes from outside the engine and is
/// validated here so a bad plan is a config error, not a zero-width
/// chunk pool or a division by zero deep in the stream.
fn validate_knobs(knobs: &SegmentKnobs, ngpus: usize) -> Result<()> {
    if knobs.block == 0 || knobs.block % ngpus != 0 {
        return Err(Error::Config(format!(
            "segment plan: block {} must be positive and divisible by ngpus {ngpus}",
            knobs.block
        )));
    }
    if knobs.host_buffers < 2 {
        return Err(Error::Config("segment plan: host_buffers must be ≥ 2".into()));
    }
    if !(2..=64).contains(&knobs.device_buffers) {
        return Err(Error::Config("segment plan: device_buffers must be in 2..=64".into()));
    }
    if knobs.lane_threads == 0 {
        return Err(Error::Config("segment plan: lane_threads must be ≥ 1".into()));
    }
    Ok(())
}
