//! One segment of the stream: a batch of column windows executed under a
//! single [`SegmentKnobs`] configuration against the engine's long-lived
//! resources.
//!
//! This is the body of paper Listing 1.3, lifted out of the old
//! monolithic pipeline with two structural changes:
//!
//! * The device lanes are **not** closed at the end of the segment. The
//!   coordinator instead tracks how many chunks each lane still owes
//!   (`outstanding`) and drains exactly those, so the lane threads — and
//!   their warmed-up kernel workers — survive into the next segment.
//!   Only the write flush and the journal *intent* append mark the
//!   boundary; the durable commit record is synced by a task running on
//!   the writer aio engine's background thread
//!   ([`AioEngine::sync_then`]) and is reaped at the **next** segment
//!   boundary, so the commit fsync overlaps the following segment's
//!   reads instead of stalling this one. Resume treats an intent with
//!   no covering commit as uncommitted and replays the segment.
//! * Blocks flow **by reference** (the zero-copy plane): the aio engine
//!   reads disk bytes straight into an aligned slab, the published
//!   [`Block`] is shared with the [`BlockCache`] by `Arc` clone, and
//!   each lane receives a [`BlockSlice`] view of its chunk instead of a
//!   memcpy'd staging buffer. A cache hit hands back the resident
//!   handle — zero bytes move. The only per-block copies left are
//!   compute-owned (the trsm solving the view into its own output, the
//!   PJRT literal-boundary pad); `Metrics`' `bytes_copied` /
//!   `bytes_borrowed` counters keep the plane honest.

use crate::coordinator::lane::{DevIn, DevOut, DeviceLane, LaneOutputs};
use crate::coordinator::metrics::{Counter, Metrics, Phase};
use crate::coordinator::pool::BufPool;
use crate::devsim::SegmentKnobs;
use crate::error::{Error, Result};
use crate::gwas::preprocess::Preprocessed;
use crate::gwas::sloop::{sloop_block_into, sloop_from_reductions_into, SloopScratch};
use crate::storage::{AioEngine, AioHandle, Block, BlockCache, BlockKey, SlabHandle, SlabPool};
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{RecvTimeoutError, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One entry of an explicit segment schedule (the testing/benchmark
/// face of the engine — the adaptive loop builds the same thing from
/// [`crate::tune::replan_knobs`] decisions).
#[derive(Debug, Clone, Copy)]
pub struct SegmentPlan {
    /// Knobs this segment streams under.
    pub knobs: SegmentKnobs,
    /// Column windows to stream (`usize::MAX` = everything remaining).
    pub windows: usize,
}

/// Pop up to `max_windows` column windows of at most `block` columns off
/// the remaining work list (splitting the front range as needed).
pub(super) fn take_windows(
    remaining: &mut VecDeque<(u64, u64)>,
    block: u64,
    max_windows: usize,
) -> Vec<(u64, usize)> {
    let mut out = Vec::new();
    while out.len() < max_windows {
        let Some((c0, len)) = remaining.pop_front() else { break };
        let take = block.min(len);
        out.push((c0, take as usize));
        if take < len {
            remaining.push_front((c0 + take, len - take));
        }
    }
    out
}

/// Per-block assembly state: the result buffer filling up chunk by chunk.
struct BlockAssembly {
    buf: Vec<f64>,
    live_total: usize,
    chunks_left: usize,
}

/// The engine resources one segment borrows. Shared references are
/// copied out where the borrow checker needs the mutable parts free.
pub(super) struct SegmentCtx<'a> {
    pub n: usize,
    pub p: usize,
    pub mb_gpu: usize,
    pub pre: &'a Preprocessed,
    pub reader: &'a AioEngine,
    pub writer: &'a AioEngine,
    pub cache: Option<&'a BlockCache>,
    pub cache_dataset: Option<&'a str>,
    pub lanes: &'a [DeviceLane],
    pub slabs: &'a SlabPool,
    pub result_pool: &'a mut BufPool,
    pub scratch: &'a mut SloopScratch,
}

/// A window's block on its way to the lanes: either the shared handle a
/// cache hit returned immediately, or a slab read still in flight.
enum PendingBlock {
    Hit(Block),
    Read(SlabHandle),
}

/// Mutable streaming state of one segment.
struct SegmentState {
    pending_writes: VecDeque<(u64, u64, AioHandle)>,
    completed: Vec<(u64, u64)>,
    assemblies: HashMap<u64, BlockAssembly>,
    live_of: HashMap<u64, usize>,
    retired: usize,
    /// Chunks submitted to each lane and not yet received back — what
    /// the end-of-segment drain collects instead of closing the lane.
    outstanding: Vec<usize>,
}

/// A lane's output channel disconnected mid-stream. Surfaced as the
/// recoverable [`Error::LaneFault`]: the engine supervisor respawns the
/// lanes and replays the segment (nothing journals until the segment
/// boundary, so a replay recomputes exactly this segment's windows).
fn lane_died(gi: usize) -> Error {
    Error::LaneFault { lane: gi, msg: "exited mid-stream".into() }
}

/// The watchdog's verdict on a lane that owes chunks but has produced
/// nothing for the whole watchdog window — wedged, not dead: its
/// channel is still open, it just stopped answering.
fn lane_wedged(gi: usize, outstanding: usize, wd_ms: u64) -> Error {
    Error::LaneFault {
        lane: gi,
        msg: format!("wedged: {outstanding} chunk(s) outstanding, no progress in {wd_ms}ms"),
    }
}

/// Re-verify a block's read-time checksum at the submit boundary; on
/// mismatch, evict the (possibly corrupt) cache entry and re-read from
/// disk — bounded by the retry policy — so corrupt bytes are never
/// computed on. One relaxed load when integrity checking is off.
#[allow(clippy::too_many_arguments)]
fn verify_or_reread(
    n: usize,
    reader: &AioEngine,
    slabs: &SlabPool,
    cache: Option<&BlockCache>,
    cache_dataset: Option<&str>,
    mut block: Block,
    col0: u64,
    live: usize,
) -> Result<Block> {
    if !crate::storage::fault::integrity_enabled() {
        return Ok(block);
    }
    let mut rereads = 0u32;
    while !block.integrity_ok() {
        let key = cache_dataset.map(|ds| BlockKey {
            dataset: ds.to_string(),
            col0,
            ncols: live as u64,
        });
        if let (Some(cache), Some(key)) = (cache, &key) {
            cache.invalidate(key);
        }
        rereads += 1;
        if rereads > crate::storage::fault::policy().read_retries.max(1) {
            return Err(Error::Pipeline(format!(
                "block at cols {col0}..{} failed integrity verification after {rereads} read(s)",
                col0 + live as u64
            )));
        }
        crate::storage::fault::note_read_retry();
        drop(block);
        let (bm, res) = reader.read_cols_slab(col0, live as u64, slabs.take(n * live)?).wait();
        res?;
        block = bm.ok_or_else(|| Error::Pipeline("re-read lost its slab".into()))?.publish();
        if let (Some(cache), Some(key)) = (cache, key) {
            cache.insert(key, &block);
        }
    }
    Ok(block)
}

/// Retire one lane result: run the CPU tail, fill the assembly, and
/// kick the write when the block is complete.
fn process_out(
    ctx: &mut SegmentCtx<'_>,
    out: DevOut,
    st: &mut SegmentState,
    metrics: &mut Metrics,
    device_secs: &mut f64,
) -> Result<()> {
    let col0 = out.block;
    let p = ctx.p;
    let mb_gpu = ctx.mb_gpu;
    st.outstanding[out.lane] = st.outstanding[out.lane].saturating_sub(1);
    crate::telemetry::lane_outstanding(out.lane, st.outstanding[out.lane]);
    metrics.add(Phase::DeviceCompute, Duration::from_secs_f64(out.compute_secs));
    metrics.add_bytes(Counter::BytesCopied, out.staged_copy_bytes);
    *device_secs += out.compute_secs;
    let live_total = *st
        .live_of
        .get(&col0)
        .ok_or_else(|| Error::Pipeline(format!("lane returned unknown window {col0}")))?;
    // Ensure an assembly buffer exists (may need to wait on a write).
    if !st.assemblies.contains_key(&col0) {
        let buf = loop {
            if let Some(buf) = ctx.result_pool.take() {
                break buf;
            }
            let (wc0, wlen, h) = st.pending_writes.pop_front().ok_or_else(|| {
                Error::Pipeline("result pool empty with no writes in flight".into())
            })?;
            let t0 = Instant::now();
            let (wbuf, res) = h.wait();
            let waited = t0.elapsed();
            metrics.add(Phase::WriteWait, waited);
            crate::telemetry::span(
                "write_wait",
                "coordinator",
                crate::telemetry::trace::TID_COORD,
                t0,
                waited,
                &[("col0", wc0)],
            );
            res?;
            st.completed.push((wc0, wlen));
            ctx.result_pool.put(wbuf);
        };
        let chunks = live_total.div_ceil(mb_gpu);
        st.assemblies.insert(col0, BlockAssembly { buf, live_total, chunks_left: chunks });
    }
    let asm = st.assemblies.get_mut(&col0).expect("assembly exists");
    let c_off = out.lane * mb_gpu; // chunk's first column within window
    let t0 = Instant::now();
    // The S-loop writes its solutions straight into this chunk's
    // segment of the assembly buffer — no per-chunk result matrix,
    // no copy: the retire path is allocation-free in steady state.
    match out.outs {
        LaneOutputs::Xbt(xbt) => {
            let live = xbt.cols();
            sloop_block_into(
                ctx.pre,
                &xbt,
                ctx.scratch,
                &mut asm.buf[c_off * p..(c_off + live) * p],
            )?;
        }
        LaneOutputs::Reductions { xbt: _, g, rb, d } => {
            let live = d.len();
            let seg = &mut asm.buf[c_off * p..(c_off + live) * p];
            sloop_from_reductions_into(ctx.pre, &g, &d, &rb, ctx.scratch, seg)?;
        }
        LaneOutputs::Solutions(rblk) => {
            let live = rblk.cols();
            asm.buf[c_off * p..(c_off + live) * p].copy_from_slice(rblk.as_slice());
        }
    }
    let sloop_took = t0.elapsed();
    metrics.add(Phase::Sloop, sloop_took);
    crate::telemetry::span(
        "sloop",
        "coordinator",
        crate::telemetry::trace::TID_COORD,
        t0,
        sloop_took,
        &[("col0", col0), ("lane", out.lane as u64)],
    );
    asm.chunks_left -= 1;
    if asm.chunks_left == 0 {
        let mut asm = st.assemblies.remove(&col0).expect("assembly exists");
        st.live_of.remove(&col0);
        asm.buf.truncate(p * asm.live_total);
        let h = ctx.writer.write_cols(col0, asm.live_total as u64, asm.buf);
        st.pending_writes.push_back((col0, asm.live_total as u64, h));
        st.retired += 1;
    }
    Ok(())
}

/// Stream one batch of column windows under a single knob configuration.
///
/// The boundary is two-phase: every persisted window gets an *intent*
/// record (buffered append, no fsync) once its data write has been
/// flushed, and the *durable commit* — data fsync + commit record +
/// journal fsync — is scheduled on the writer aio engine's background
/// thread via [`AioEngine::sync_then`]. The commit handle lands in
/// `pending_commit` and is reaped at the start of the **next** boundary
/// (or by the caller after the last segment), so the fsync latency
/// overlaps the next segment's reads. Device-compute seconds accumulate
/// into `device_secs`.
pub(super) fn run_segment(
    mut ctx: SegmentCtx<'_>,
    items: &[(u64, usize)],
    metrics: &mut Metrics,
    journal: &Arc<Mutex<crate::coordinator::journal::Journal>>,
    pending_commit: &mut Option<AioHandle>,
    device_secs: &mut f64,
) -> Result<()> {
    let n = ctx.n;
    let mb_gpu = ctx.mb_gpu;
    let ngpus = ctx.lanes.len();
    let lanes = ctx.lanes; // shared ref, copied out so `ctx` can be &mut
    let reader = ctx.reader;
    let slabs = ctx.slabs;
    let cache = ctx.cache;
    let cache_dataset = ctx.cache_dataset;

    let mut st = SegmentState {
        pending_writes: VecDeque::new(),
        completed: Vec::new(),
        assemblies: HashMap::new(),
        live_of: HashMap::new(),
        retired: 0,
        outstanding: vec![0; ngpus],
    };
    let njobs = items.len();
    let read_ahead = slabs.target().saturating_sub(1).max(1);
    let block_key = |ds: &str, col0: u64, live: usize| BlockKey {
        dataset: ds.to_string(),
        col0,
        ncols: live as u64,
    };

    // ---- pipeline state ------------------------------------------------
    // (window col0, the block: resident handle or in-flight slab read)
    let mut pending_reads: VecDeque<(u64, PendingBlock)> = VecDeque::new();
    let mut next_read = 0usize; // index into `items`

    // Stage windows up to the slab ring's read-ahead. With a shared
    // cache attached, each window first probes it: a hit *is* the block
    // (the resident handle, shared by reference — no disk I/O, no
    // memcpy), a miss takes a slab and goes to the aio engine; the
    // published block is inserted into the cache on arrival.
    macro_rules! pump_reads {
        () => {
            while next_read < njobs && pending_reads.len() < read_ahead {
                let (col0, live) = items[next_read];
                let mut pending = None;
                if let (Some(cache), Some(ds)) = (cache, cache_dataset) {
                    let key = block_key(ds, col0, live);
                    let t0 = Instant::now();
                    // A resident block must still match its read-time
                    // checksum; a corrupt entry is evicted and the
                    // window falls through to a fresh disk read.
                    let resident = cache.get(&key, n * live).filter(|b| {
                        if !crate::storage::fault::integrity_enabled() || b.integrity_ok() {
                            true
                        } else {
                            cache.invalidate(&key);
                            crate::storage::fault::note_read_retry();
                            false
                        }
                    });
                    if let Some(block) = resident {
                        let took = t0.elapsed();
                        metrics.add(Phase::CacheHit, took);
                        crate::telemetry::span(
                            "cache_hit",
                            "coordinator",
                            crate::telemetry::trace::TID_COORD,
                            t0,
                            took,
                            &[("col0", col0)],
                        );
                        metrics.add_bytes(Counter::BytesBorrowed, block.bytes());
                        pending = Some(PendingBlock::Hit(block));
                    } else {
                        metrics.add(Phase::CacheMiss, Duration::ZERO);
                    }
                }
                let pending = match pending {
                    Some(p) => p,
                    None => {
                        let buf = slabs.take(n * live)?;
                        PendingBlock::Read(reader.read_cols_slab(col0, live as u64, buf))
                    }
                };
                pending_reads.push_back((col0, pending));
                next_read += 1;
            }
        };
    }

    // ---- main loop (Listing 1.3) ----------------------------------------
    for &(col0, live_total) in items {
        st.live_of.insert(col0, live_total);
        pump_reads!();
        let (rc0, pending) = pending_reads
            .pop_front()
            .ok_or_else(|| Error::Pipeline("no pending read (ring starved?)".into()))?;
        debug_assert_eq!(rc0, col0);
        let block = match pending {
            PendingBlock::Hit(block) => block,
            PendingBlock::Read(handle) => {
                let t0 = Instant::now();
                let (buf, res) = handle.wait(); // aio_wait Xr[b]
                let waited = t0.elapsed();
                metrics.add(Phase::ReadWait, waited);
                crate::telemetry::span(
                    "read_wait",
                    "coordinator",
                    crate::telemetry::trace::TID_COORD,
                    t0,
                    waited,
                    &[("col0", col0)],
                );
                res?;
                let block = buf.expect("completed read returns its slab").publish();
                // A freshly read (miss) window becomes cache residency
                // for the next job streaming this dataset — an `Arc`
                // clone of the very slab the disk filled, not a copy.
                if let (Some(cache), Some(ds)) = (cache, cache_dataset) {
                    cache.insert(block_key(ds, col0, live_total), &block);
                    metrics.add_bytes(Counter::BytesBorrowed, block.bytes());
                }
                block
            }
        };
        // Integrity gate at the submit boundary: both a cache hit and a
        // fresh read re-verify here, so corruption anywhere between the
        // disk and this point is caught before any lane computes on it.
        let block =
            verify_or_reread(n, reader, slabs, cache, cache_dataset, block, col0, live_total)?;
        let chunks = live_total.div_ceil(mb_gpu);

        // Split-send views to the lanes (cu_send; a Full bounce is the
        // paper's cu_send_wait — spent draining results, not idling:
        // this is where the S-loop of block b-1 overlaps the trsm of b).
        for gi in 0..chunks {
            let live = (live_total - gi * mb_gpu).min(mb_gpu);
            let t0 = Instant::now();
            let view = block.slice(gi * mb_gpu * n, n * live);
            metrics.add_bytes(Counter::BytesBorrowed, (n * live * 8) as u64);
            let mut item = DevIn { block: col0, view, live };
            metrics.add(Phase::Send, t0.elapsed());
            loop {
                match lanes[gi].try_submit(item) {
                    Ok(()) => break,
                    Err(TrySendError::Full(bounced)) => {
                        item = bounced;
                        let t0 = Instant::now();
                        // Wait in watchdog-sized slices instead of a
                        // bare recv(): a wedged lane would otherwise
                        // park the coordinator here forever.
                        let out = loop {
                            match lanes[gi].rx_out.recv_timeout(Duration::from_millis(20)) {
                                Ok(out) => break out,
                                Err(RecvTimeoutError::Timeout) => {
                                    let wd = crate::storage::fault::policy().lane_watchdog_ms;
                                    if wd > 0 && t0.elapsed() >= Duration::from_millis(wd) {
                                        return Err(lane_wedged(gi, st.outstanding[gi], wd));
                                    }
                                }
                                Err(RecvTimeoutError::Disconnected) => return Err(lane_died(gi)),
                            }
                        };
                        let waited = t0.elapsed();
                        metrics.add(Phase::RecvWait, waited);
                        crate::telemetry::span(
                            "recv_wait",
                            "coordinator",
                            crate::telemetry::trace::TID_COORD,
                            t0,
                            waited,
                            &[("lane", gi as u64)],
                        );
                        process_out(&mut ctx, out, &mut st, metrics, device_secs)?;
                    }
                    Err(TrySendError::Disconnected(_)) => return Err(lane_died(gi)),
                }
            }
            st.outstanding[gi] += 1;
            crate::telemetry::lane_outstanding(gi, st.outstanding[gi]);
        }
        drop(block); // lanes + cache hold their own references now

        // Drain any already-finished results without blocking.
        for gi in 0..ngpus {
            while st.outstanding[gi] > 0 {
                match lanes[gi].rx_out.try_recv() {
                    Ok(out) => process_out(&mut ctx, out, &mut st, metrics, device_secs)?,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => return Err(lane_died(gi)),
                }
            }
        }
    }

    // ---- drain ----------------------------------------------------------
    // The lanes stay alive (they are the engine's, not the segment's):
    // collect exactly the chunks each lane still owes us. The watchdog
    // rides the existing 20ms poll: a lane owing chunks that produces
    // nothing for the whole window is declared wedged (recoverable).
    let mut last_progress = Instant::now();
    while st.retired < njobs {
        let Some(gi) = (0..ngpus).find(|&gi| st.outstanding[gi] > 0) else {
            return Err(Error::Pipeline(format!(
                "pipeline stalled after {}/{njobs} blocks with no chunks in flight",
                st.retired
            )));
        };
        let t0 = Instant::now();
        match lanes[gi].rx_out.recv_timeout(Duration::from_millis(20)) {
            Ok(out) => {
                let waited = t0.elapsed();
                metrics.add(Phase::RecvWait, waited);
                crate::telemetry::span(
                    "recv_wait",
                    "coordinator",
                    crate::telemetry::trace::TID_COORD,
                    t0,
                    waited,
                    &[("lane", gi as u64)],
                );
                process_out(&mut ctx, out, &mut st, metrics, device_secs)?;
                last_progress = Instant::now();
            }
            Err(RecvTimeoutError::Timeout) => {
                let wd = crate::storage::fault::policy().lane_watchdog_ms;
                if wd > 0 && last_progress.elapsed() >= Duration::from_millis(wd) {
                    return Err(lane_wedged(gi, st.outstanding[gi], wd));
                }
            }
            Err(RecvTimeoutError::Disconnected) => return Err(lane_died(gi)),
        }
    }
    // Flush writes.
    while let Some((wc0, wlen, h)) = st.pending_writes.pop_front() {
        let t0 = Instant::now();
        let (wbuf, res) = h.wait();
        let waited = t0.elapsed();
        metrics.add(Phase::WriteWait, waited);
        crate::telemetry::span(
            "write_wait",
            "coordinator",
            crate::telemetry::trace::TID_COORD,
            t0,
            waited,
            &[("col0", wc0)],
        );
        res?;
        st.completed.push((wc0, wlen));
        ctx.result_pool.put(wbuf);
    }
    // ---- two-phase journal boundary --------------------------------------
    // Reap the *previous* segment's durable commit before appending this
    // segment's intents: the on-disk record order stays strictly
    // `intents, commit, intents, commit, …`, which is what resume's
    // "a commit covers exactly the pending intents before it" rule
    // expects. A commit failure therefore surfaces one boundary late —
    // but always before any newer intents are written over it.
    if let Some(h) = pending_commit.take() {
        let t0 = Instant::now();
        let (_, res) = h.wait();
        let waited = t0.elapsed();
        metrics.add(Phase::WriteWait, waited);
        crate::telemetry::span(
            "journal_commit_wait",
            "coordinator",
            crate::telemetry::trace::TID_COORD,
            t0,
            waited,
            &[],
        );
        res?;
    }
    // Intent phase: record what this segment handed to the writer. No
    // fsync here — an intent without a covering commit is replayed on
    // resume (result writes are idempotent), so a buffered append is
    // enough and the boundary never stalls on the journal.
    let n_intents = {
        let mut jn = journal.lock().unwrap_or_else(|e| e.into_inner());
        let mut n = 0u64;
        for (wc0, wlen) in st.completed.drain(..) {
            jn.append_intent(wc0, wlen)?;
            n += 1;
        }
        n
    };
    // Durable phase: data fsync + commit record + journal fsync, all on
    // the writer's I/O thread *behind* every write queued above (the
    // queue is FIFO). The next segment's reads overlap this.
    if n_intents > 0 {
        let jn = Arc::clone(journal);
        *pending_commit = Some(ctx.writer.sync_then(move |sync_res| {
            sync_res?;
            jn.lock().unwrap_or_else(|e| e.into_inner()).commit(n_intents)
        }));
    }
    Ok(())
}
