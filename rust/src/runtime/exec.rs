//! PJRT execution engine: load HLO text, compile once, run many.
//!
//! One [`Engine`] per thread (PJRT handles in the `xla` crate are not
//! `Send`, and per-lane clients mirror the paper's one-CUDA-context-per-GPU
//! model). Inputs/outputs are flat `f64` buffers + dims; layout contracts
//! with the AOT graphs are documented in `python/compile/model.py` and
//! enforced by the conversion helpers in [`super::layout`].

use crate::error::{Error, Result};
use crate::runtime::artifact::ArtifactEntry;
use std::collections::HashMap;
use std::path::Path;

/// A typed flat tensor crossing the PJRT boundary (row-major).
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub dims: Vec<i64>,
    pub data: Vec<f64>,
}

impl HostTensor {
    pub fn new(dims: Vec<i64>, data: Vec<f64>) -> Result<Self> {
        let want: i64 = dims.iter().product();
        if want as usize != data.len() {
            return Err(Error::shape(format!(
                "HostTensor: dims {dims:?} imply {want} elements, got {}",
                data.len()
            )));
        }
        Ok(HostTensor { dims, data })
    }

    pub fn scalar_count(&self) -> usize {
        self.data.len()
    }
}

/// Build an XLA literal from a host tensor (copies the buffer).
pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    xla::Literal::vec1(&t.data)
        .reshape(&t.dims)
        .map_err(|e| Error::Runtime(format!("literal reshape {:?}: {e}", t.dims)))
}

/// A compiled artifact, executable on this thread.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Human tag for error messages.
    tag: String,
}

impl Executable {
    /// Run with the given inputs; returns the flattened tuple outputs.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Run with pre-built literals (the hot path: constant inputs such as
    /// `L`/`Dinv` are converted once per lane, not once per block —
    /// see EXPERIMENTS.md §Perf). Accepts borrowed literals so callers
    /// can mix cached and per-call inputs without moves.
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        literals: &[L],
    ) -> Result<Vec<HostTensor>> {
        let result = self
            .exe
            .execute::<L>(literals)
            .map_err(|e| Error::Runtime(format!("{}: execute: {e}", self.tag)))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("{}: fetch: {e}", self.tag)))?;
        // aot.py lowers with return_tuple=True: unpack every element.
        let parts = out
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("{}: tuple unpack: {e}", self.tag)))?;
        parts
            .into_iter()
            .enumerate()
            .map(|(i, lit)| {
                let shape = lit
                    .array_shape()
                    .map_err(|e| Error::Runtime(format!("{}: out {i} shape: {e}", self.tag)))?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data = lit
                    .to_vec::<f64>()
                    .map_err(|e| Error::Runtime(format!("{}: out {i} to_vec: {e}", self.tag)))?;
                HostTensor::new(dims, data)
            })
            .collect()
    }
}

/// Per-thread PJRT client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("creating PJRT CPU client: {e}")))?;
        Ok(Engine { client, cache: HashMap::new() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (uncached).
    pub fn compile_file(&self, path: &Path, tag: &str) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| Error::Runtime(format!("parsing {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compiling {}: {e}", path.display())))?;
        Ok(Executable { exe, tag: tag.to_string() })
    }

    /// Compile a manifest entry, caching by path.
    pub fn load(&mut self, entry: &ArtifactEntry) -> Result<&Executable> {
        let key = entry.path.to_string_lossy().into_owned();
        if !self.cache.contains_key(&key) {
            let tag = format!("{}(n={},mb={})", entry.key.kind.as_str(), entry.key.n, entry.key.mb);
            let exe = self.compile_file(&entry.path, &tag)?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_check() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    // PJRT-dependent tests live in rust/tests/runtime_integration.rs and
    // are gated on built artifacts; here we only check pure logic.
}
