//! Layout conversions between the rust coordinator's column-major world
//! and the row-major XLA literal world.
//!
//! The contract (see `python/compile/model.py`): block data crosses the
//! boundary as "SNP-rows" — an `(mb, n)` row-major tensor whose flat image
//! equals the column-major `(n, mb)` disk block. These helpers produce the
//! remaining (cold-path) conversions; the hot-path block buffers cross
//! with **zero copies or transposes** by construction.

use crate::linalg::Matrix;

/// Column-major `Matrix` → row-major flat buffer (cold path: `L`, `X̃_L`).
pub fn matrix_to_rowmajor(m: &Matrix) -> Vec<f64> {
    let (r, c) = (m.rows(), m.cols());
    let mut out = vec![0.0; r * c];
    for i in 0..r {
        for j in 0..c {
            out[i * c + j] = m.get(i, j);
        }
    }
    out
}

/// Row-major flat buffer → column-major `Matrix` (cold path).
pub fn rowmajor_to_matrix(rows: usize, cols: usize, data: &[f64]) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            m.set(i, j, data[i * cols + j]);
        }
    }
    m
}

/// Convert `potrf_invert_diag_blocks` output (an `nb × nb·nblocks`
/// column-major matrix, block k in columns `k*nb..`) into the `(n, nb)`
/// row-major stack the AOT kernels expect (block k in rows `k*nb..`).
pub fn dinv_to_rowmajor(dinv: &Matrix, nb: usize, n: usize) -> Vec<f64> {
    let nblocks = n / nb;
    debug_assert_eq!(dinv.rows(), nb);
    debug_assert!(dinv.cols() >= nb * nblocks);
    let mut out = vec![0.0; n * nb];
    for k in 0..nblocks {
        for r in 0..nb {
            for c in 0..nb {
                out[(k * nb + r) * nb + c] = dinv.get(r, k * nb + c);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{potrf, potrf_invert_diag_blocks};
    use crate::util::XorShift;

    #[test]
    fn rowmajor_roundtrip() {
        let mut rng = XorShift::new(1);
        let m = Matrix::randn(5, 3, &mut rng);
        let flat = matrix_to_rowmajor(&m);
        assert_eq!(flat[0 * 3 + 2], m.get(0, 2));
        assert_eq!(flat[4 * 3 + 1], m.get(4, 1));
        let back = rowmajor_to_matrix(5, 3, &flat);
        assert_eq!(back, m);
    }

    #[test]
    fn block_buffer_needs_no_conversion() {
        // The defining property: col-major (n, mb) flat == row-major (mb, n) flat.
        let mut rng = XorShift::new(2);
        let n = 4;
        let mb = 3;
        let block = Matrix::randn(n, mb, &mut rng); // col-major (n, mb)
        let as_rowmajor_mbn = block.as_slice(); // claim: this is (mb, n) row-major
        for s in 0..mb {
            for i in 0..n {
                assert_eq!(as_rowmajor_mbn[s * n + i], block.get(i, s));
            }
        }
    }

    #[test]
    fn dinv_layout_matches_python() {
        let mut rng = XorShift::new(3);
        let nb = 4;
        let n = 12;
        let m = Matrix::rand_spd(n, 2.0, &mut rng);
        let l = potrf(&m).unwrap();
        let dinv = potrf_invert_diag_blocks(&l, nb).unwrap();
        let flat = dinv_to_rowmajor(&dinv, nb, n);
        assert_eq!(flat.len(), n * nb);
        // Row k*nb+r, col c of the (n, nb) row-major stack == dinv[r, k*nb+c].
        for k in 0..3 {
            for r in 0..nb {
                for c in 0..nb {
                    assert_eq!(flat[(k * nb + r) * nb + c], dinv.get(r, k * nb + c));
                }
            }
        }
    }
}
