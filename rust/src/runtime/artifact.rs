//! Artifact manifest: what `python/compile/aot.py` produced and where.
//!
//! `artifacts/manifest.tsv` maps `(kind, shape)` keys to HLO text files.
//! The runtime looks artifacts up by the exact shapes the coordinator is
//! about to stream; a missing artifact is a configuration error reported
//! with the available alternatives.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// The graph kinds aot.py emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Kind {
    /// Study preprocessing (runs once).
    Preprocess,
    /// Device does only the trsm (the paper's exact split).
    Trsm,
    /// Device does trsm + fused S-loop reductions.
    Block,
    /// Device returns final per-SNP solutions (full-offload ablation).
    BlockFull,
}

impl Kind {
    pub fn parse(s: &str) -> Result<Kind> {
        match s {
            "preprocess" => Ok(Kind::Preprocess),
            "trsm" => Ok(Kind::Trsm),
            "block" => Ok(Kind::Block),
            "blockfull" => Ok(Kind::BlockFull),
            other => Err(Error::format(format!("unknown artifact kind '{other}'"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Kind::Preprocess => "preprocess",
            Kind::Trsm => "trsm",
            Kind::Block => "block",
            Kind::BlockFull => "blockfull",
        }
    }
}

/// Shape key of one artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ArtifactKey {
    pub kind: Kind,
    pub n: usize,
    pub pl: usize,
    /// Block width (SNP columns per device call). 0 for `Preprocess`.
    pub mb: usize,
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub key: ArtifactKey,
    /// Diagonal block size baked into the kernel.
    pub nb: usize,
    /// Column tile baked into the kernel grid.
    pub bm: usize,
    pub path: PathBuf,
}

/// Parsed manifest with lookup by key.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: BTreeMap<ArtifactKey, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::io(format!("reading {} (run `make artifacts`?)", path.display()), e)
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` is prepended to file names.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 8 {
                return Err(Error::format(format!(
                    "manifest line {}: expected 8 tab-separated fields, got {}",
                    lineno + 1,
                    fields.len()
                )));
            }
            let kind = Kind::parse(fields[0])?;
            let parse_num = |s: &str, what: &str| -> Result<usize> {
                s.parse().map_err(|_| Error::format(format!("manifest: bad {what} '{s}'")))
            };
            let n = parse_num(fields[1], "n")?;
            let pl = parse_num(fields[2], "pl")?;
            let mb = parse_num(fields[3], "mb")?;
            let nb = parse_num(fields[4], "nb")?;
            let bm = parse_num(fields[5], "bm")?;
            if fields[6] != "f64" {
                return Err(Error::format(format!("manifest: unsupported dtype {}", fields[6])));
            }
            let key = ArtifactKey { kind, n, pl, mb: if kind == Kind::Preprocess { 0 } else { mb } };
            let entry = ArtifactEntry { key, nb, bm, path: dir.join(fields[7]) };
            if entries.insert(key, entry).is_some() {
                return Err(Error::format(format!("manifest: duplicate key {key:?}")));
            }
        }
        Ok(Manifest { entries })
    }

    /// Exact lookup.
    pub fn get(&self, key: &ArtifactKey) -> Result<&ArtifactEntry> {
        self.entries.get(key).ok_or_else(|| {
            let available: Vec<String> = self
                .entries
                .keys()
                .filter(|k| k.kind == key.kind)
                .map(|k| format!("(n={}, pl={}, mb={})", k.n, k.pl, k.mb))
                .collect();
            Error::Config(format!(
                "no '{}' artifact for n={}, pl={}, mb={}; available: [{}] — \
                 re-run `make artifacts` with a matching profile",
                key.kind.as_str(),
                key.n,
                key.pl,
                key.mb,
                available.join(", ")
            ))
        })
    }

    /// All entries of a kind (for CLI listings).
    pub fn of_kind(&self, kind: Kind) -> Vec<&ArtifactEntry> {
        self.entries.values().filter(|e| e.key.kind == kind).collect()
    }

    /// Shapes available for block-processing kinds, useful for choosing a
    /// compatible (n, mb) when planning a run.
    pub fn block_shapes(&self, kind: Kind, pl: usize) -> Vec<(usize, usize)> {
        self.entries
            .keys()
            .filter(|k| k.kind == kind && k.pl == pl)
            .map(|k| (k.n, k.mb))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# kind\tn\tpl\tmb\tnb\tbm\tdtype\tfile
trsm\t64\t3\t32\t16\t16\tf64\ttrsm_a.hlo.txt
block\t64\t3\t32\t16\t16\tf64\tblock_a.hlo.txt
preprocess\t64\t3\t32\t16\t16\tf64\tpre_a.hlo.txt
";

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.len(), 3);
        let e = m
            .get(&ArtifactKey { kind: Kind::Trsm, n: 64, pl: 3, mb: 32 })
            .unwrap();
        assert_eq!(e.nb, 16);
        assert_eq!(e.path, PathBuf::from("/art/trsm_a.hlo.txt"));
        // Preprocess keys normalize mb to 0.
        assert!(m.get(&ArtifactKey { kind: Kind::Preprocess, n: 64, pl: 3, mb: 0 }).is_ok());
    }

    #[test]
    fn missing_artifact_reports_alternatives() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        let err = m
            .get(&ArtifactKey { kind: Kind::Trsm, n: 999, pl: 3, mb: 32 })
            .unwrap_err()
            .to_string();
        assert!(err.contains("n=64"), "{err}");
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("trsm\t64\t3\n", Path::new("/a")).is_err()); // too few
        assert!(Manifest::parse("warp\t64\t3\t32\t16\t16\tf64\tx\n", Path::new("/a")).is_err()); // bad kind
        assert!(Manifest::parse("trsm\t64\t3\t32\t16\t16\tf32\tx\n", Path::new("/a")).is_err()); // dtype
        assert!(Manifest::parse("trsm\tx\t3\t32\t16\t16\tf64\tx\n", Path::new("/a")).is_err()); // number
        let dup = format!("{SAMPLE}trsm\t64\t3\t32\t16\t16\tf64\tother.hlo.txt\n");
        assert!(Manifest::parse(&dup, Path::new("/a")).is_err());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let m = Manifest::parse("# hi\n\n  \n", Path::new("/a")).unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn block_shapes_filters() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.block_shapes(Kind::Trsm, 3), vec![(64, 32)]);
        assert!(m.block_shapes(Kind::Trsm, 9).is_empty());
    }
}
