//! Runtime layer: load the AOT-compiled HLO artifacts and execute them on
//! the PJRT CPU client from the rust hot path.
//!
//! Start-to-finish: `Manifest::load` finds the artifact for the requested
//! `(kind, n, pl, mb)`, `Engine::load` parses the HLO **text** (the
//! interchange format — see `python/compile/aot.py`), compiles it once,
//! and `Executable::run` moves flat f64 buffers across with the layout
//! contract of [`layout`].

pub mod artifact;
pub mod exec;
pub mod layout;

pub use artifact::{ArtifactEntry, ArtifactKey, Kind, Manifest};
pub use exec::{Engine, Executable, HostTensor};
pub use layout::{dinv_to_rowmajor, matrix_to_rowmajor, rowmajor_to_matrix};

/// Default artifacts directory relative to the repo root / CWD.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("CUGWAS_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
