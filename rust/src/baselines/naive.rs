//! The naive offload — paper Fig. 3: "applications in which GPU-offloading
//! is an after-thought". Identical work to the pipeline, but every step is
//! synchronous and serialized: read, send, trsm, recv, S-loop, write —
//! the device idles during I/O and the CPU idles during device compute.
//!
//! Shares the lane machinery with the real pipeline (a single lane, one
//! outstanding chunk, fully waited) so the comparison isolates the
//! *schedule*, not the implementation.

use crate::coordinator::lane::{Backend, DevIn, DeviceLane, LaneOutputs, OffloadMode};
use crate::coordinator::metrics::{Metrics, Phase};
use crate::coordinator::pipeline::BackendKind;
use crate::error::{Error, Result};
use crate::gwas::preprocess::preprocess;
use crate::gwas::sloop::{sloop_block, SloopScratch};
use crate::linalg::Matrix;
use crate::runtime::{ArtifactKey, Kind, Manifest};
use crate::storage::{dataset, Header, SlabPool, Throttle, XrdFile};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Run summary.
#[derive(Debug)]
pub struct NaiveReport {
    pub blocks: usize,
    pub snps: usize,
    pub wall_secs: f64,
    pub snps_per_sec: f64,
    pub metrics: Metrics,
}

/// Serialized offload run; results land in `r.xrd`.
pub fn run_naive(
    dataset_dir: &Path,
    block: usize,
    backend: &BackendKind,
    read_throttle: Option<Throttle>,
) -> Result<NaiveReport> {
    if block == 0 {
        return Err(Error::Config("block must be positive".into()));
    }
    let (meta, kin, xl, y) = dataset::load_sidecars(dataset_dir)?;
    let dims = meta.dims;
    let n = dims.n;
    let p = dims.p();
    let t_wall = Instant::now();
    let mut metrics = Metrics::new();

    let (lane_backend, dinv_nb) = match backend {
        BackendKind::Native => (Backend::Native, 0),
        BackendKind::Pjrt { artifacts } => {
            let manifest = Manifest::load(artifacts)?;
            let entry = manifest
                .get(&ArtifactKey { kind: Kind::Trsm, n, pl: dims.pl, mb: block })?
                .clone();
            let nb = entry.nb;
            (Backend::Pjrt { entry }, nb)
        }
    };
    let pre = Arc::new(preprocess(&kin, &xl, &y, dinv_nb)?);

    let paths = dataset::DatasetPaths::new(dataset_dir);
    let xr = XrdFile::open(&paths.xr())?.with_throttle(read_throttle);
    let r_header = Header::new(p as u64, dims.m as u64, block.min(dims.m) as u64, meta.seed)?;
    let rfile = XrdFile::create(&paths.results(), r_header)?;

    // Single synchronous lane — it may use the whole pool (threads = 0).
    let lane = DeviceLane::spawn(0, OffloadMode::Trsm, lane_backend, &pre, block, 0, 2)?;
    let nblocks = dims.m.div_ceil(block);
    let cols_in =
        |b: usize| if (b + 1) * block <= dims.m { block } else { dims.m - b * block };
    let mut scratch = SloopScratch::new(dims.pl);
    // One slab, fully recycled per block — even the naive schedule rides
    // the zero-copy plane (the comparison isolates the *schedule*).
    let slabs = SlabPool::new(1, n * block);

    for b in 0..nblocks {
        let live = cols_in(b);
        // Synchronous read — the device idles.
        let t0 = Instant::now();
        let mut buf = slabs.take(n * live)?;
        xr.read_cols_into((b * block) as u64, live as u64, buf.as_mut_slice())?;
        metrics.add(Phase::ReadWait, t0.elapsed());
        // Send + trsm + recv, fully waited — the CPU idles.
        let t0 = Instant::now();
        let published = buf.publish();
        lane.submit(DevIn { block: b as u64, view: published.slice(0, n * live), live })?;
        drop(published);
        let out = lane
            .rx_out
            .recv()
            .map_err(|_| Error::Pipeline("naive lane died".into()))?;
        metrics.add(Phase::RecvWait, t0.elapsed());
        let xbt = match out.outs {
            LaneOutputs::Xbt(x) => x,
            _ => return Err(Error::Pipeline("naive expects trsm outputs".into())),
        };
        // S-loop — the device idles.
        let t0 = Instant::now();
        let mut rblk = Matrix::zeros(p, live);
        sloop_block(&pre, &xbt, &mut scratch, &mut rblk)?;
        metrics.add(Phase::Sloop, t0.elapsed());
        // Synchronous write.
        let t0 = Instant::now();
        rfile.write_cols((b * block) as u64, live as u64, rblk.as_slice())?;
        metrics.add(Phase::WriteWait, t0.elapsed());
    }
    rfile.sync()?;
    let lane_metrics = lane.join()?;
    metrics.merge(&lane_metrics);

    let wall_secs = t_wall.elapsed().as_secs_f64();
    Ok(NaiveReport {
        blocks: nblocks,
        snps: dims.m,
        wall_secs,
        snps_per_sec: dims.m as f64 / wall_secs.max(1e-12),
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify_against_oracle;
    use crate::gwas::problem::Dims;
    use crate::storage::generate;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cugwas_naive_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn naive_matches_oracle() {
        let dir = tmpdir("oracle");
        generate(&dir, Dims::new(20, 2, 21).unwrap(), 8, 7).unwrap();
        let report = run_naive(&dir, 8, &BackendKind::Native, None).unwrap();
        assert_eq!(report.blocks, 3);
        verify_against_oracle(&dir, 1e-8).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn naive_phases_are_disjointly_accounted() {
        let dir = tmpdir("phases");
        generate(&dir, Dims::new(20, 2, 16).unwrap(), 8, 3).unwrap();
        let report = run_naive(&dir, 8, &BackendKind::Native, None).unwrap();
        for ph in [Phase::ReadWait, Phase::RecvWait, Phase::Sloop, Phase::WriteWait] {
            assert!(report.metrics.count(ph) >= 2, "{ph:?}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
