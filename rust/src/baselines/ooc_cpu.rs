//! OOC-HP-GWAS — paper Listing 1.2: the CPU-only out-of-core baseline.
//!
//! Double-buffered reads (`aio_read Xr[b+1]` while block `b` computes),
//! blocked BLAS-3 trsm on the CPU, S-loop, synchronous result writes.
//! This is the implementation the paper credits with >90 % CPU efficiency
//! and the reference point for cuGWAS's 2.6× (Fig. 6a).

use crate::coordinator::metrics::{Metrics, Phase};
use crate::error::{Error, Result};
use crate::gwas::preprocess::preprocess;
use crate::gwas::sloop::{sloop_block, SloopScratch};
use crate::linalg::{trsm_lower_left, Matrix};
use crate::storage::{dataset, AioEngine, Header, Throttle, XrdFile};
use std::path::Path;
use std::time::Instant;

/// Run summary (mirrors `PipelineReport` where it makes sense).
#[derive(Debug)]
pub struct OocReport {
    pub blocks: usize,
    pub snps: usize,
    pub wall_secs: f64,
    pub snps_per_sec: f64,
    pub metrics: Metrics,
}

/// Stream the dataset with the CPU-only algorithm; results land in `r.xrd`.
pub fn run_ooc_cpu(
    dataset_dir: &Path,
    block: usize,
    read_throttle: Option<Throttle>,
) -> Result<OocReport> {
    if block == 0 {
        return Err(Error::Config("block must be positive".into()));
    }
    let (meta, kin, xl, y) = dataset::load_sidecars(dataset_dir)?;
    let dims = meta.dims;
    let n = dims.n;
    let p = dims.p();
    let t_wall = Instant::now();
    let mut metrics = Metrics::new();

    // Listing 1.2 lines 1–5.
    let pre = preprocess(&kin, &xl, &y, 0)?;

    let paths = dataset::DatasetPaths::new(dataset_dir);
    let xr = XrdFile::open(&paths.xr())?.with_throttle(read_throttle);
    let r_header = Header::new(p as u64, dims.m as u64, block.min(dims.m) as u64, meta.seed)?;
    let rfile = XrdFile::create(&paths.results(), r_header)?;
    let reader = AioEngine::new(xr);
    let writer = AioEngine::new(rfile);

    let nblocks = dims.m.div_ceil(block);
    let cols_in =
        |b: usize| if (b + 1) * block <= dims.m { block } else { dims.m - b * block };

    // Double buffering: read b+1 while computing b (Listing 1.2 lines 6–9).
    let mut spare: Vec<f64> = vec![0.0; n * block];
    let mut scratch = SloopScratch::new(dims.pl);
    let mut pending_write: Option<crate::storage::AioHandle> = None;
    let mut wbuf: Option<Vec<f64>> = Some(vec![0.0; p * block]);

    // aio_read Xr[1]
    let mut next: Option<crate::storage::AioHandle> = {
        let mut buf = std::mem::take(&mut spare);
        buf.truncate(n * cols_in(0));
        Some(reader.read_cols(0, cols_in(0) as u64, buf))
    };
    for b in 0..nblocks {
        // aio_wait Xr[b]
        let t0 = Instant::now();
        let (buf, res) = next.take().expect("read in flight").wait();
        metrics.add(Phase::ReadWait, t0.elapsed());
        res?;
        // aio_read Xr[b+1]
        if b + 1 < nblocks {
            let mut nbuf = std::mem::take(&mut spare);
            nbuf.resize(n * block, 0.0);
            nbuf.truncate(n * cols_in(b + 1));
            next = Some(reader.read_cols(((b + 1) * block) as u64, cols_in(b + 1) as u64, nbuf));
        }
        let live = cols_in(b);
        // Xrb ← trsm L, Xrb  (line 10)
        let t0 = Instant::now();
        let mut xb = Matrix::from_vec(n, live, buf)?;
        trsm_lower_left(&pre.l, &mut xb)?;
        metrics.add(Phase::DeviceCompute, t0.elapsed()); // "compute" lane
        // S-loop (lines 11–15)
        let t0 = Instant::now();
        let mut rblk = Matrix::zeros(p, live);
        sloop_block(&pre, &xb, &mut scratch, &mut rblk)?;
        metrics.add(Phase::Sloop, t0.elapsed());
        // Write results (double-buffered too).
        if let Some(h) = pending_write.take() {
            let t0 = Instant::now();
            let (done_buf, res) = h.wait();
            metrics.add(Phase::WriteWait, t0.elapsed());
            res?;
            wbuf = Some(done_buf);
        }
        let mut out_buf = wbuf.take().expect("write buffer available");
        out_buf.resize(p * block, 0.0);
        out_buf.truncate(p * live);
        out_buf.copy_from_slice(rblk.as_slice());
        pending_write = Some(writer.write_cols((b * block) as u64, live as u64, out_buf));
        // Recycle the data buffer for the next prefetch.
        spare = xb.into_vec();
    }
    if let Some(h) = pending_write.take() {
        let (_, res) = h.wait();
        res?;
    }
    writer.sync().wait().1?;

    let wall_secs = t_wall.elapsed().as_secs_f64();
    Ok(OocReport {
        blocks: nblocks,
        snps: dims.m,
        wall_secs,
        snps_per_sec: dims.m as f64 / wall_secs.max(1e-12),
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify_against_oracle;
    use crate::gwas::problem::Dims;
    use crate::storage::generate;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cugwas_ooc_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn ooc_cpu_matches_oracle() {
        let dir = tmpdir("oracle");
        generate(&dir, Dims::new(24, 3, 37).unwrap(), 8, 5).unwrap();
        let report = run_ooc_cpu(&dir, 8, None).unwrap();
        assert_eq!(report.blocks, 5);
        verify_against_oracle(&dir, 1e-8).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ooc_cpu_single_partial_block() {
        let dir = tmpdir("partial");
        generate(&dir, Dims::new(16, 2, 3).unwrap(), 3, 2).unwrap();
        run_ooc_cpu(&dir, 8, None).unwrap();
        verify_against_oracle(&dir, 1e-8).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn ooc_cpu_rejects_zero_block() {
        let dir = tmpdir("zero");
        generate(&dir, Dims::new(16, 2, 4).unwrap(), 2, 2).unwrap();
        assert!(run_ooc_cpu(&dir, 0, None).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
