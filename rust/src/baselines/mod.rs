//! The comparison systems the paper measures against, implemented in
//! full (not stubs) so the benches can regenerate every figure:
//!
//! * [`ooc_cpu`] — OOC-HP-GWAS (paper Listing 1.2): the CPU-only
//!   out-of-core algorithm with double-buffered asynchronous reads.
//!   The paper's primary baseline (Fig. 6a).
//! * [`naive`] — GPU offload as an afterthought (paper Fig. 3): same
//!   work as the pipeline, fully serialized.
//! * [`probabel`] — a per-SNP BLAS-2 solver in the style of the
//!   "widespread biology library" (ProbABEL, `--mmscore`): no blocking,
//!   no out-of-core machinery, explicit `M^-1` application per SNP.

pub mod naive;
pub mod ooc_cpu;
pub mod probabel;

pub use naive::run_naive;
pub use ooc_cpu::run_ooc_cpu;
pub use probabel::run_probabel;
