//! ProbABEL-like per-SNP baseline — the "widespread biology library" of
//! the paper's 488× headline.
//!
//! Faithful to the *algorithmic structure* of ProbABEL's `--mmscore`
//! linear model (Aulchenko et al., 2010): `M^-1` is precomputed once, but
//! every SNP then pays its own BLAS-2 work — a dense `M^-1 · x_i` gemv
//! (`O(n²)` per SNP!), small gram-matrix assembly, and an unblocked solve.
//! No column blocking, no BLAS-3, no I/O overlap: the disk is read one
//! SNP column at a time. This is the gap OOC-HP-GWAS and cuGWAS close.

use crate::coordinator::metrics::{Metrics, Phase};
use crate::error::Result;
use crate::gwas::problem::Dims;
use crate::linalg::{chol::posv_small, dot, gemv_n, posv, Matrix};
use crate::storage::{dataset, Header, XrdFile};
use std::path::Path;
use std::time::Instant;

/// Run summary.
#[derive(Debug)]
pub struct ProbabelReport {
    pub snps: usize,
    pub wall_secs: f64,
    pub snps_per_sec: f64,
    pub metrics: Metrics,
}

/// Solve the study one SNP at a time; results land in `r.xrd`.
pub fn run_probabel(dataset_dir: &Path) -> Result<ProbabelReport> {
    let (meta, kin, xl, y) = dataset::load_sidecars(dataset_dir)?;
    let dims: Dims = meta.dims;
    let n = dims.n;
    let pl = dims.pl;
    let p = dims.p();
    let t_wall = Instant::now();
    let mut metrics = Metrics::new();

    // Once-per-study work (mmscore precomputes the inverse variance
    // matrix): M^-1 column by column, M^-1 X_L, M^-1 y.
    let t0 = Instant::now();
    let mut minv = Matrix::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        posv(&kin, &mut e)?;
        minv.col_mut(j).copy_from_slice(&e);
    }
    let minv_xl = {
        let mut m = Matrix::zeros(n, pl);
        crate::linalg::gemm(1.0, &minv, &xl, 0.0, &mut m)?;
        m
    };
    let minv_y = gemv_n(&minv, &y)?;
    let xl_minv_xl = {
        let mut m = Matrix::zeros(pl, pl);
        crate::linalg::gemm(1.0, &xl.transpose(), &minv_xl, 0.0, &mut m)?;
        m
    };
    let xl_minv_y: Vec<f64> = (0..pl).map(|k| dot(xl.col(k), &minv_y)).collect();
    metrics.add(Phase::Other, t0.elapsed());

    let paths = dataset::DatasetPaths::new(dataset_dir);
    let xr = XrdFile::open(&paths.xr())?;
    let r_header = Header::new(p as u64, dims.m as u64, 1.max(dims.m.min(1024)) as u64, meta.seed)?;
    let rfile = XrdFile::create(&paths.results(), r_header)?;

    // Per-SNP loop: the whole point — O(n²) gemv per SNP.
    let mut xri = vec![0.0; n];
    let mut s = vec![0.0; p * p];
    let mut rhs = vec![0.0; p];
    let mut rcol = vec![0.0; p];
    for i in 0..dims.m {
        let t0 = Instant::now();
        xr.read_cols_into(i as u64, 1, &mut xri)?; // one column at a time
        metrics.add(Phase::ReadWait, t0.elapsed());
        let t0 = Instant::now();
        // v = M^-1 x_i  — the per-SNP BLAS-2 bottleneck.
        let v = gemv_n(&minv, &xri)?;
        // Assemble S_i = [[XL' Minv XL, XL' v], [v' XL, x' v]] and rhs.
        for c in 0..pl {
            for r in 0..pl {
                s[c * p + r] = xl_minv_xl.get(r, c);
            }
        }
        for k in 0..pl {
            let b = dot(xl.col(k), &v);
            s[pl * p + k] = b;
            s[k * p + pl] = b;
        }
        s[pl * p + pl] = dot(&xri, &v);
        rhs[..pl].copy_from_slice(&xl_minv_y);
        rhs[pl] = dot(&v, &y);
        rcol.copy_from_slice(&rhs);
        posv_small(&mut s, &mut rcol, p)?;
        metrics.add(Phase::Sloop, t0.elapsed());
        let t0 = Instant::now();
        rfile.write_cols(i as u64, 1, &rcol)?;
        metrics.add(Phase::WriteWait, t0.elapsed());
    }
    rfile.sync()?;

    let wall_secs = t_wall.elapsed().as_secs_f64();
    Ok(ProbabelReport {
        snps: dims.m,
        wall_secs,
        snps_per_sec: dims.m as f64 / wall_secs.max(1e-12),
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::verify_against_oracle;
    use crate::storage::generate;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cugwas_pa_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn probabel_matches_oracle() {
        // Same numbers (different algorithm, same math) as the fast paths.
        let dir = tmpdir("oracle");
        generate(&dir, Dims::new(20, 3, 9).unwrap(), 4, 11).unwrap();
        let report = run_probabel(&dir).unwrap();
        assert_eq!(report.snps, 9);
        verify_against_oracle(&dir, 1e-6).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn probabel_reads_one_column_at_a_time() {
        let dir = tmpdir("cols");
        generate(&dir, Dims::new(16, 2, 7).unwrap(), 3, 2).unwrap();
        let report = run_probabel(&dir).unwrap();
        assert_eq!(report.metrics.count(Phase::ReadWait), 7);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
