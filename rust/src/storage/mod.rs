//! Storage layer: the XRD on-disk block format, dataset directories, the
//! synchronous positioned-I/O core, the asynchronous engine providing
//! the paper's `aio_read` / `aio_wait` / `aio_write` primitives, the
//! refcounted slab plane that lets blocks flow by reference, the
//! shared block cache that amortizes disk reads across studies, and the
//! fault plane (injection, retry policy, block checksums) that keeps
//! long streams alive through transient device errors.

pub mod aio;
pub mod cache;
pub mod dataset;
pub mod fault;
pub mod format;
pub mod slab;
pub mod xrd;

pub use aio::{
    probe_read_bandwidth, probe_read_bandwidth_windowed, AioEngine, AioHandle, AioStats, ReadProbe,
    SlabHandle,
};
pub use cache::{BlockCache, BlockKey, CacheStats};
pub use fault::{FaultCounters, FaultPlan, RetryPolicy};
pub use slab::{Block, BlockMut, BlockSlice, SlabPool, SlabStats};
pub use dataset::{
    generate, generate_with_dtype, load_meta, load_sidecars, load_xr_incore, DatasetPaths, Meta,
};
pub use format::{Dtype, Header};
pub use xrd::{Throttle, XrdFile};
