//! Storage layer: the XRD on-disk block format, dataset directories, the
//! synchronous positioned-I/O core, and the asynchronous engine providing
//! the paper's `aio_read` / `aio_wait` / `aio_write` primitives.

pub mod aio;
pub mod dataset;
pub mod format;
pub mod xrd;

pub use aio::{AioEngine, AioHandle};
pub use dataset::{generate, generate_with_dtype, load_sidecars, load_xr_incore, DatasetPaths, Meta};
pub use format::{Dtype, Header};
pub use xrd::{Throttle, XrdFile};
