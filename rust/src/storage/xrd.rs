//! Random-access block I/O over XRD files (positioned reads/writes, the
//! synchronous core the async engine drives).
//!
//! An optional [`Throttle`] models a target storage device's bandwidth:
//! the paper's numbers come from spinning disks (~120 MB/s) while this
//! testbed has fast NVMe, so benches that need HDD-like behaviour inject a
//! throttle — the code path (positioned I/O + overlap) stays identical.

use crate::error::{Error, Result};
use crate::storage::format::{
    f32s_as_bytes, f32s_as_bytes_mut, f64s_as_bytes, f64s_as_bytes_mut, Dtype, Header,
};
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::time::{Duration, Instant};

/// Bandwidth throttle emulating a slower storage device.
#[derive(Debug, Clone, Copy)]
pub struct Throttle {
    pub bytes_per_sec: f64,
}

impl Throttle {
    /// Sleep long enough that `bytes` over the whole op take at least
    /// `bytes / bytes_per_sec`, accounting for the time already spent.
    fn pace(&self, bytes: u64, started: Instant) {
        let target = Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
        let spent = started.elapsed();
        if target > spent {
            std::thread::sleep(target - spent);
        }
    }
}

/// An open XRD file with its parsed header.
pub struct XrdFile {
    file: File,
    header: Header,
    throttle: Option<Throttle>,
}

impl XrdFile {
    /// Open an existing XRD file for reading.
    pub fn open(path: &Path) -> Result<Self> {
        let file = File::open(path).map_err(|e| Error::io(format!("open {}", path.display()), e))?;
        Self::from_file(file, path)
    }

    /// Open an existing XRD file for reading and writing (resume path).
    pub fn open_rw(path: &Path) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .map_err(|e| Error::io(format!("open rw {}", path.display()), e))?;
        Self::from_file(file, path)
    }

    fn from_file(file: File, path: &Path) -> Result<Self> {
        let mut hbuf = [0u8; crate::storage::format::HEADER_BYTES];
        file.read_exact_at(&mut hbuf, 0)
            .map_err(|e| Error::io("reading XRD header", e))?;
        let header = Header::from_bytes(&hbuf)?;
        // Validate the advertised size against reality up front so
        // truncation surfaces at open, not mid-stream.
        let len = file.metadata().map_err(|e| Error::io("stat", e))?.len();
        if len < header.file_bytes() {
            return Err(Error::format(format!(
                "{}: file is {len} bytes, header implies {}",
                path.display(),
                header.file_bytes()
            )));
        }
        Ok(XrdFile { file, header, throttle: None })
    }

    /// Create a new XRD file (e.g. the results file), preallocated.
    pub fn create(path: &Path, header: Header) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(|e| Error::io(format!("create {}", path.display()), e))?;
        file.write_all_at(&header.to_bytes(), 0)
            .map_err(|e| Error::io("writing header", e))?;
        file.set_len(header.file_bytes())
            .map_err(|e| Error::io("preallocating", e))?;
        Ok(XrdFile { file, header, throttle: None })
    }

    /// Attach a bandwidth throttle (returns self for chaining).
    pub fn with_throttle(mut self, t: Option<Throttle>) -> Self {
        self.throttle = t;
        self
    }

    pub fn header(&self) -> &Header {
        &self.header
    }

    /// Read block `b` into `buf` (must hold exactly the block's elements).
    /// One contiguous positioned read.
    pub fn read_block_into(&self, b: u64, buf: &mut [f64]) -> Result<()> {
        let h = &self.header;
        if b >= h.block_count() {
            return Err(Error::format(format!("block {b} out of range (count {})", h.block_count())));
        }
        let want = (h.cols_in_block(b) * h.rows) as usize;
        if buf.len() != want {
            return Err(Error::shape(format!("block {b} needs {want} f64s, buffer has {}", buf.len())));
        }
        let t0 = Instant::now();
        self.read_elems_at(buf, h.block_offset(b), &format!("block {b}"))?;
        if let Some(t) = self.throttle {
            t.pace(h.block_bytes(b), t0);
        }
        Ok(())
    }

    /// Positioned element read with on-disk dtype conversion (in-memory is
    /// always f64; `Dtype::F32` files are widened on load — the paper's
    /// footnote-3 "halve the storage" mode).
    fn read_elems_at(&self, buf: &mut [f64], offset: u64, what: &str) -> Result<()> {
        match self.header.dtype {
            Dtype::F64 => self
                .file
                .read_exact_at(f64s_as_bytes_mut(buf), offset)
                .map_err(|e| Error::io(format!("reading {what}"), e)),
            Dtype::F32 => {
                let mut tmp = vec![0f32; buf.len()];
                self.file
                    .read_exact_at(f32s_as_bytes_mut(&mut tmp), offset)
                    .map_err(|e| Error::io(format!("reading {what}"), e))?;
                for (d, s) in buf.iter_mut().zip(&tmp) {
                    *d = *s as f64;
                }
                Ok(())
            }
        }
    }

    /// Positioned element write with dtype conversion (narrowing for F32).
    fn write_elems_at(&self, buf: &[f64], offset: u64, what: &str) -> Result<()> {
        match self.header.dtype {
            Dtype::F64 => self
                .file
                .write_all_at(f64s_as_bytes(buf), offset)
                .map_err(|e| Error::io(format!("writing {what}"), e)),
            Dtype::F32 => {
                let tmp: Vec<f32> = buf.iter().map(|&v| v as f32).collect();
                self.file
                    .write_all_at(f32s_as_bytes(&tmp), offset)
                    .map_err(|e| Error::io(format!("writing {what}"), e))
            }
        }
    }

    /// Write block `b` from `buf`.
    pub fn write_block(&self, b: u64, buf: &[f64]) -> Result<()> {
        let h = &self.header;
        if b >= h.block_count() {
            return Err(Error::format(format!("block {b} out of range (count {})", h.block_count())));
        }
        let want = (h.cols_in_block(b) * h.rows) as usize;
        if buf.len() != want {
            return Err(Error::shape(format!("block {b} needs {want} f64s, buffer has {}", buf.len())));
        }
        let t0 = Instant::now();
        self.write_elems_at(buf, h.block_offset(b), &format!("block {b}"))?;
        if let Some(t) = self.throttle {
            t.pace(h.block_bytes(b), t0);
        }
        Ok(())
    }

    /// Flush file contents to stable storage.
    pub fn sync(&self) -> Result<()> {
        self.file.sync_data().map_err(|e| Error::io("sync", e))
    }

    /// Read columns `[col0, col0+ncols)` into `buf` (one contiguous
    /// positioned read — columns are contiguous on disk regardless of the
    /// header's block structure, so the pipeline may pick any iteration
    /// block size).
    pub fn read_cols_into(&self, col0: u64, ncols: u64, buf: &mut [f64]) -> Result<()> {
        let h = &self.header;
        self.check_cols(col0, ncols, buf.len())?;
        let off = crate::storage::format::HEADER_BYTES as u64 + col0 * h.rows * h.dtype.bytes();
        let t0 = Instant::now();
        self.read_elems_at(buf, off, &format!("cols {col0}+{ncols}"))?;
        if let Some(t) = self.throttle {
            t.pace(ncols * h.rows * h.dtype.bytes(), t0);
        }
        Ok(())
    }

    /// Write columns `[col0, col0+ncols)` from `buf`.
    pub fn write_cols(&self, col0: u64, ncols: u64, buf: &[f64]) -> Result<()> {
        let h = &self.header;
        self.check_cols(col0, ncols, buf.len())?;
        let off = crate::storage::format::HEADER_BYTES as u64 + col0 * h.rows * h.dtype.bytes();
        let t0 = Instant::now();
        self.write_elems_at(buf, off, &format!("cols {col0}+{ncols}"))?;
        if let Some(t) = self.throttle {
            t.pace(ncols * h.rows * h.dtype.bytes(), t0);
        }
        Ok(())
    }

    fn check_cols(&self, col0: u64, ncols: u64, buf_len: usize) -> Result<()> {
        let h = &self.header;
        if col0 + ncols > h.cols {
            return Err(Error::format(format!(
                "cols {col0}+{ncols} out of range (file has {})",
                h.cols
            )));
        }
        let want = (ncols * h.rows) as usize;
        if buf_len != want {
            return Err(Error::shape(format!(
                "cols {col0}+{ncols} need {want} f64s, buffer has {buf_len}"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cugwas_xrd_{}_{tag}.xrd", std::process::id()))
    }

    #[test]
    fn create_write_read_roundtrip() {
        let p = tmpfile("rw");
        let h = Header::new(4, 10, 3, 0).unwrap(); // blocks 3,3,3,1
        let f = XrdFile::create(&p, h).unwrap();
        for b in 0..h.block_count() {
            let n = (h.cols_in_block(b) * h.rows) as usize;
            let data: Vec<f64> = (0..n).map(|i| (b * 1000) as f64 + i as f64).collect();
            f.write_block(b, &data).unwrap();
        }
        drop(f);
        let f = XrdFile::open(&p).unwrap();
        assert_eq!(*f.header(), h);
        for b in 0..h.block_count() {
            let n = (h.cols_in_block(b) * h.rows) as usize;
            let mut buf = vec![0.0; n];
            f.read_block_into(b, &mut buf).unwrap();
            assert_eq!(buf[0], (b * 1000) as f64);
            assert_eq!(buf[n - 1], (b * 1000) as f64 + (n - 1) as f64);
        }
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn wrong_buffer_size_rejected() {
        let p = tmpfile("badbuf");
        let h = Header::new(4, 6, 2, 0).unwrap();
        let f = XrdFile::create(&p, h).unwrap();
        let mut small = vec![0.0; 4];
        assert!(f.read_block_into(0, &mut small).is_err());
        assert!(f.write_block(0, &small).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn out_of_range_block_rejected() {
        let p = tmpfile("oob");
        let h = Header::new(2, 4, 2, 0).unwrap();
        let f = XrdFile::create(&p, h).unwrap();
        let mut buf = vec![0.0; 4];
        assert!(f.read_block_into(2, &mut buf).is_err());
        assert!(f.write_block(9, &buf).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn truncated_file_detected_at_open() {
        let p = tmpfile("trunc");
        let h = Header::new(8, 8, 4, 0).unwrap();
        XrdFile::create(&p, h).unwrap();
        let f = OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(h.file_bytes() - 16).unwrap();
        drop(f);
        assert!(XrdFile::open(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn throttle_slows_reads() {
        let p = tmpfile("throttle");
        let h = Header::new(64, 16, 16, 0).unwrap(); // one 8 KiB block
        let f = XrdFile::create(&p, h).unwrap();
        let data = vec![1.0; 64 * 16];
        f.write_block(0, &data).unwrap();
        drop(f);
        // 8192 bytes at 1 MB/s → ≥ ~8 ms.
        let f = XrdFile::open(&p)
            .unwrap()
            .with_throttle(Some(Throttle { bytes_per_sec: 1e6 }));
        let mut buf = vec![0.0; 64 * 16];
        let t0 = Instant::now();
        f.read_block_into(0, &mut buf).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(7), "{:?}", t0.elapsed());
        std::fs::remove_file(&p).unwrap();
    }
}
