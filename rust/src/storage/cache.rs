//! Shared, byte-budgeted block cache — the amortization layer of the
//! multi-study service.
//!
//! The paper streams ONE study's `X_R` from disk at the platter's pace;
//! when *many* studies read the same dataset (re-runs, permutation
//! batches, multi-trait analyses), every job after the first can be fed
//! from RAM instead. The cache sits between the pipeline's `aio_read`
//! and the disk: a read first probes the cache, and a miss's freshly
//! read block is inserted on arrival, so the HDD sees each block at
//! most once per residency.
//!
//! Design constraints, in the spirit of the pipeline's fixed pools:
//!
//! * **Hard byte budget** — the cache never exceeds `capacity_bytes`;
//!   insertion evicts least-recently-used entries first. A budget of 0
//!   disables caching entirely (every probe misses, nothing is stored).
//! * **Copy in, copy out** — entries are owned copies. The pipeline's
//!   buffer-rotation invariant (fixed pools, zero steady-state
//!   allocation) is untouched; a hit is one `memcpy` at RAM speed,
//!   which is exactly the regime the paper's Fig. 3 calls "free"
//!   relative to an HDD read.
//! * **Shared + thread-safe** — one `Arc<BlockCache>` is handed to all
//!   service workers; a single mutex suffices because the critical
//!   sections are memcpys, orders of magnitude shorter than the disk
//!   reads they replace.
//!
//! Hit/miss counts surface both here ([`CacheStats`]) and as
//! `Phase::CacheHit` / `Phase::CacheMiss` in the per-job
//! [`coordinator::metrics`](crate::coordinator::Metrics).

use std::collections::HashMap;
use std::sync::Mutex;

/// Identity of one streamed block of one dataset file.
///
/// Keyed by column range rather than block ordinal so that jobs with
/// different pipeline block sizes never alias each other's data.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BlockKey {
    /// Canonical dataset identity (the canonicalized dataset directory).
    pub dataset: String,
    /// First column of the block within the XRD file.
    pub col0: u64,
    /// Column count of the block.
    pub ncols: u64,
}

/// Point-in-time cache counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Bytes currently resident.
    pub bytes: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Configured budget.
    pub capacity_bytes: u64,
}

#[derive(Debug)]
struct Entry {
    data: Vec<f64>,
    /// Last-touch logical timestamp (monotone per cache).
    stamp: u64,
}

#[derive(Debug, Default)]
struct Inner {
    map: HashMap<BlockKey, Entry>,
    bytes: u64,
    clock: u64,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

/// Reference-counted LRU block cache (see module docs).
#[derive(Debug)]
pub struct BlockCache {
    inner: Mutex<Inner>,
    capacity_bytes: u64,
}

impl BlockCache {
    /// A cache holding at most `capacity_bytes` of block data. 0 disables.
    pub fn new(capacity_bytes: u64) -> Self {
        BlockCache { inner: Mutex::new(Inner::default()), capacity_bytes }
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Probe for `key`; on a hit, copy the block into `buf` (whose length
    /// must equal the entry's) and refresh its recency. Every probe is
    /// counted as a hit or a miss — the pipeline probes exactly once per
    /// block, so `misses` equals the disk reads actually issued.
    pub fn get_into(&self, key: &BlockKey, buf: &mut [f64]) -> bool {
        let mut guard = self.inner.lock().expect("cache lock poisoned");
        let inner = &mut *guard;
        inner.clock += 1;
        let stamp = inner.clock;
        match inner.map.get_mut(key) {
            Some(e) if e.data.len() == buf.len() => {
                buf.copy_from_slice(&e.data);
                e.stamp = stamp;
                inner.hits += 1;
                true
            }
            _ => {
                inner.misses += 1;
                false
            }
        }
    }

    /// Insert (a copy of) a block, evicting LRU entries until it fits.
    /// Blocks larger than the whole budget are not cached.
    pub fn insert(&self, key: BlockKey, data: &[f64]) {
        let bytes = (data.len() * std::mem::size_of::<f64>()) as u64;
        if bytes == 0 || bytes > self.capacity_bytes {
            return;
        }
        let mut guard = self.inner.lock().expect("cache lock poisoned");
        let inner = &mut *guard;
        if let Some(old) = inner.map.remove(&key) {
            inner.bytes -= (old.data.len() * std::mem::size_of::<f64>()) as u64;
        }
        while inner.bytes + bytes > self.capacity_bytes {
            let Some(lru) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            let old = inner.map.remove(&lru).expect("lru entry exists");
            inner.bytes -= (old.data.len() * std::mem::size_of::<f64>()) as u64;
            inner.evictions += 1;
        }
        inner.clock += 1;
        let stamp = inner.clock;
        inner.bytes += bytes;
        inner.insertions += 1;
        inner.map.insert(key, Entry { data: data.to_vec(), stamp });
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().expect("cache lock poisoned");
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            insertions: g.insertions,
            evictions: g.evictions,
            bytes: g.bytes,
            entries: g.map.len(),
            capacity_bytes: self.capacity_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(ds: &str, col0: u64) -> BlockKey {
        BlockKey { dataset: ds.to_string(), col0, ncols: 4 }
    }

    #[test]
    fn hit_returns_data_and_counts() {
        let c = BlockCache::new(1 << 20);
        let data = vec![1.0, 2.0, 3.0, 4.0];
        c.insert(key("a", 0), &data);
        let mut buf = vec![0.0; 4];
        assert!(c.get_into(&key("a", 0), &mut buf));
        assert_eq!(buf, data);
        assert!(!c.get_into(&key("a", 4), &mut buf)); // absent
        assert!(!c.get_into(&key("b", 0), &mut buf)); // other dataset
        let s = c.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 2);
        assert_eq!(s.insertions, 1);
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 32);
    }

    #[test]
    fn lru_eviction_under_budget() {
        // Budget of exactly two 4-element blocks (64 bytes).
        let c = BlockCache::new(64);
        c.insert(key("a", 0), &[0.0; 4]);
        c.insert(key("a", 4), &[1.0; 4]);
        // Touch block 0 so block 4 becomes the LRU.
        let mut buf = vec![0.0; 4];
        assert!(c.get_into(&key("a", 0), &mut buf));
        // A third block evicts the LRU (block 4), not the recently-used.
        c.insert(key("a", 8), &[2.0; 4]);
        assert!(c.get_into(&key("a", 0), &mut buf), "recently used survives");
        assert!(c.get_into(&key("a", 8), &mut buf), "new entry resident");
        assert!(!c.get_into(&key("a", 4), &mut buf), "LRU evicted");
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert!(s.bytes <= 64);
    }

    #[test]
    fn oversized_block_is_not_cached() {
        let c = BlockCache::new(16); // < one 4-element block
        c.insert(key("a", 0), &[0.0; 4]);
        let s = c.stats();
        assert_eq!(s.insertions, 0);
        assert_eq!(s.entries, 0);
        assert_eq!(s.bytes, 0);
    }

    #[test]
    fn zero_budget_disables() {
        let c = BlockCache::new(0);
        c.insert(key("a", 0), &[1.0; 4]);
        let mut buf = vec![0.0; 4];
        assert!(!c.get_into(&key("a", 0), &mut buf));
        assert_eq!(c.stats().entries, 0);
    }

    #[test]
    fn reinsert_replaces_without_leaking_bytes() {
        let c = BlockCache::new(1 << 10);
        c.insert(key("a", 0), &[1.0; 4]);
        c.insert(key("a", 0), &[2.0; 4]);
        let s = c.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 32);
        let mut buf = vec![0.0; 4];
        assert!(c.get_into(&key("a", 0), &mut buf));
        assert_eq!(buf, vec![2.0; 4]);
    }

    #[test]
    fn length_mismatch_is_a_miss() {
        let c = BlockCache::new(1 << 10);
        c.insert(key("a", 0), &[1.0; 4]);
        let mut short = vec![0.0; 3];
        assert!(!c.get_into(&key("a", 0), &mut short));
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let c = Arc::new(BlockCache::new(1 << 20));
        c.insert(key("a", 0), &[7.0; 4]);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    let mut buf = vec![0.0; 4];
                    assert!(c.get_into(&key("a", 0), &mut buf));
                    assert_eq!(buf, vec![7.0; 4]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.stats().hits, 4);
    }
}
