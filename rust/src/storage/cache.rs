//! Shared, byte-budgeted block cache — the amortization layer of the
//! multi-study service.
//!
//! The paper streams ONE study's `X_R` from disk at the platter's pace;
//! when *many* studies read the same dataset (re-runs, permutation
//! batches, multi-trait analyses), every job after the first can be fed
//! from RAM instead. The cache sits between the pipeline's `aio_read`
//! and the disk: a read first probes the cache, and a miss's freshly
//! read block is inserted on arrival, so the HDD sees each block at
//! most once per residency.
//!
//! Design constraints, in the spirit of the pipeline's fixed pools:
//!
//! * **Hard byte budget** — the cache never exceeds `capacity_bytes`
//!   of *pinned* memory: entries are charged their slab's full capacity
//!   ([`Block::resident_bytes`] — a tail window published short still
//!   keeps its whole slab alive), and insertion evicts
//!   least-recently-used entries (by those bytes, not entry count)
//!   until the newcomer fits. A budget of 0 disables caching entirely
//!   (every probe misses, nothing is stored).
//! * **Share, don't copy** — entries are refcounted
//!   [`Block`](crate::storage::slab::Block) handles into the very slabs
//!   the aio engine read from disk: an insert is an `Arc` clone (no
//!   `to_vec`), a hit hands the same `Arc` back (no memcpy), and an
//!   eviction cannot invalidate a handle a pipeline still streams from —
//!   the slab only returns to its pool when the last holder drops.
//! * **O(1) eviction** — entries are threaded on an intrusive LRU list
//!   (index links inside the node slab), so a hit's recency bump and an
//!   eviction are both constant-time; the old full-map `min_by_key`
//!   scan made every insert O(entries) once the budget filled.
//! * **Shared + thread-safe** — one `Arc<BlockCache>` is handed to all
//!   service workers; a single mutex suffices because the critical
//!   sections are now pointer moves, orders of magnitude shorter than
//!   the disk reads they replace.
//!
//! Hit/miss counts surface both here ([`CacheStats`]) and as
//! `Phase::CacheHit` / `Phase::CacheMiss` in the per-job
//! [`coordinator::metrics`](crate::coordinator::Metrics); the bytes the
//! sharing saves show up as the metrics' `bytes_borrowed` counter.

use crate::storage::slab::Block;
use std::collections::HashMap;
use std::sync::Mutex;

/// Identity of one streamed block of one dataset file.
///
/// Keyed by column range rather than block ordinal so that jobs with
/// different pipeline block sizes never alias each other's data.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BlockKey {
    /// Canonical dataset identity (the canonicalized dataset directory).
    pub dataset: String,
    /// First column of the block within the XRD file.
    pub col0: u64,
    /// Column count of the block.
    pub ncols: u64,
}

/// Point-in-time cache counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Bytes currently resident — slab capacities pinned by the entries
    /// ([`Block::resident_bytes`]), not just published lengths.
    pub bytes: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Configured budget.
    pub capacity_bytes: u64,
}

/// Sentinel for "no neighbor" in the intrusive list.
const NIL: usize = usize::MAX;

/// One resident entry: the shared block handle plus its LRU links
/// (indices into `Inner::nodes` — the intrusive list).
#[derive(Debug)]
struct Node {
    key: BlockKey,
    block: Block,
    prev: usize,
    next: usize,
}

#[derive(Debug)]
struct Inner {
    map: HashMap<BlockKey, usize>,
    /// Node slab; `None` slots are on the free list.
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    /// Most-recently-used node (NIL when empty)…
    head: usize,
    /// …and least-recently-used (the eviction end).
    tail: usize,
    bytes: u64,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

impl Inner {
    /// Unlink node `i` from the LRU list (it stays in the slab).
    fn unlink(&mut self, i: usize) {
        let (prev, next) = {
            let n = self.nodes[i].as_ref().expect("linked node exists");
            (n.prev, n.next)
        };
        match prev {
            NIL => self.head = next,
            p => self.nodes[p].as_mut().expect("prev exists").next = next,
        }
        match next {
            NIL => self.tail = prev,
            x => self.nodes[x].as_mut().expect("next exists").prev = prev,
        }
    }

    /// Link node `i` at the MRU end.
    fn push_front(&mut self, i: usize) {
        let old_head = self.head;
        {
            let n = self.nodes[i].as_mut().expect("node exists");
            n.prev = NIL;
            n.next = old_head;
        }
        match old_head {
            NIL => self.tail = i,
            h => self.nodes[h].as_mut().expect("head exists").prev = i,
        }
        self.head = i;
    }

    /// Remove node `i` entirely: unlink, free the slot, release the map
    /// entry and its bytes. Returns the block handle (the caller decides
    /// whether anything still references it).
    fn remove(&mut self, i: usize) -> Block {
        self.unlink(i);
        let node = self.nodes[i].take().expect("node exists");
        self.free.push(i);
        self.map.remove(&node.key);
        self.bytes -= node.block.resident_bytes();
        node.block
    }

    fn insert_node(&mut self, key: BlockKey, block: Block) {
        let node = Node { key: key.clone(), block, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(i) => {
                self.nodes[i] = Some(node);
                i
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        self.map.insert(key, i);
        self.push_front(i);
    }
}

impl Default for Inner {
    fn default() -> Inner {
        Inner {
            map: HashMap::new(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            bytes: 0,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
        }
    }
}

/// Refcounted LRU block cache (see module docs).
#[derive(Debug)]
pub struct BlockCache {
    inner: Mutex<Inner>,
    capacity_bytes: u64,
}

impl BlockCache {
    /// A cache holding at most `capacity_bytes` of block data. 0 disables.
    pub fn new(capacity_bytes: u64) -> Self {
        BlockCache { inner: Mutex::new(Inner::default()), capacity_bytes }
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Probe for `key`, expecting a block of `len` f64 elements. A hit
    /// hands back a clone of the shared handle (zero memcpy) and bumps
    /// its recency in O(1). Every probe is counted as a hit or a miss —
    /// the pipeline probes exactly once per block, so `misses` equals
    /// the disk reads actually issued. A resident entry whose length
    /// disagrees with `len` counts as a miss (never alias bad geometry).
    pub fn get(&self, key: &BlockKey, len: usize) -> Option<Block> {
        let mut guard = self.inner.lock().expect("cache lock poisoned");
        let inner = &mut *guard;
        match inner.map.get(key).copied() {
            Some(i) if inner.nodes[i].as_ref().expect("mapped node").block.len() == len => {
                inner.unlink(i);
                inner.push_front(i);
                inner.hits += 1;
                Some(inner.nodes[i].as_ref().expect("mapped node").block.clone())
            }
            _ => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Insert a shared handle to `block` (an `Arc` clone — the cache and
    /// the pipeline reference the same slab), evicting LRU entries until
    /// its bytes fit. The budget is charged what the entry actually
    /// *pins* — the slab's full capacity, not just the published length
    /// (a tail window published short still keeps its whole slab
    /// resident). Blocks pinning more than the whole budget are not
    /// cached.
    pub fn insert(&self, key: BlockKey, block: &Block) {
        let bytes = block.resident_bytes();
        if block.bytes() == 0 || bytes > self.capacity_bytes {
            return;
        }
        let mut guard = self.inner.lock().expect("cache lock poisoned");
        let inner = &mut *guard;
        if let Some(i) = inner.map.get(&key).copied() {
            inner.remove(i);
        }
        while inner.bytes + bytes > self.capacity_bytes {
            let lru = inner.tail;
            if lru == NIL {
                break;
            }
            inner.remove(lru);
            inner.evictions += 1;
        }
        inner.bytes += bytes;
        inner.insertions += 1;
        inner.insert_node(key, block.clone());
    }

    /// Drop `key`'s entry, if resident. The fault-tolerance path calls
    /// this when a resident block fails integrity verification — the
    /// corrupt handle must not be served to the next probe. Returns
    /// whether an entry was removed. Handles already held elsewhere stay
    /// valid (refcounted), they are just no longer reachable here.
    pub fn invalidate(&self, key: &BlockKey) -> bool {
        let mut guard = self.inner.lock().expect("cache lock poisoned");
        let inner = &mut *guard;
        match inner.map.get(key).copied() {
            Some(i) => {
                inner.remove(i);
                true
            }
            None => false,
        }
    }

    /// Evict LRU entries until at most `target_bytes` remain resident,
    /// returning the bytes released. The disk-space sentinel calls this
    /// with 0 when a filesystem drops under its low-water mark: cached
    /// blocks are pure amortization, so they are the first ballast
    /// overboard. Handles still held by a streaming pipeline stay valid
    /// (refcounted) — only the cache's own pins are released.
    pub fn shed(&self, target_bytes: u64) -> u64 {
        let mut guard = self.inner.lock().expect("cache lock poisoned");
        let inner = &mut *guard;
        let before = inner.bytes;
        while inner.bytes > target_bytes {
            let lru = inner.tail;
            if lru == NIL {
                break;
            }
            inner.remove(lru);
            inner.evictions += 1;
        }
        before - inner.bytes
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().expect("cache lock poisoned");
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            insertions: g.insertions,
            evictions: g.evictions,
            bytes: g.bytes,
            entries: g.map.len(),
            capacity_bytes: self.capacity_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::slab::SlabPool;

    fn key(ds: &str, col0: u64) -> BlockKey {
        BlockKey { dataset: ds.to_string(), col0, ncols: 4 }
    }

    fn block(pool: &SlabPool, len: usize, v: f64) -> Block {
        let mut bm = pool.take(len).unwrap();
        bm.as_mut_slice().fill(v);
        bm.publish()
    }

    #[test]
    fn hit_returns_the_shared_handle_and_counts() {
        let pool = SlabPool::new(4, 4);
        let c = BlockCache::new(1 << 20);
        c.insert(key("a", 0), &block(&pool, 4, 1.5));
        let got = c.get(&key("a", 0), 4).expect("hit");
        assert_eq!(got.as_slice(), &[1.5; 4][..]);
        assert!(c.get(&key("a", 4), 4).is_none()); // absent
        assert!(c.get(&key("b", 0), 4).is_none()); // other dataset
        let s = c.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 2);
        assert_eq!(s.insertions, 1);
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 32);
    }

    #[test]
    fn lru_eviction_under_budget() {
        let pool = SlabPool::new(4, 4);
        // Budget of exactly two 4-element blocks (64 bytes).
        let c = BlockCache::new(64);
        c.insert(key("a", 0), &block(&pool, 4, 0.0));
        c.insert(key("a", 4), &block(&pool, 4, 1.0));
        // Touch block 0 so block 4 becomes the LRU.
        assert!(c.get(&key("a", 0), 4).is_some());
        // A third block evicts the LRU (block 4), not the recently-used.
        c.insert(key("a", 8), &block(&pool, 4, 2.0));
        assert!(c.get(&key("a", 0), 4).is_some(), "recently used survives");
        assert!(c.get(&key("a", 8), 4).is_some(), "new entry resident");
        assert!(c.get(&key("a", 4), 4).is_none(), "LRU evicted");
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert!(s.bytes <= 64);
    }

    #[test]
    fn eviction_walks_the_lru_tail_in_order() {
        let pool = SlabPool::new(8, 4);
        // Six entries at 32 bytes each under a 4-entry budget: the two
        // oldest *untouched* entries go, the refreshed one stays.
        let c = BlockCache::new(4 * 32);
        for i in 0..4u64 {
            c.insert(key("a", i * 4), &block(&pool, 4, i as f64));
        }
        assert!(c.get(&key("a", 0), 4).is_some(), "refresh the oldest");
        c.insert(key("a", 16), &block(&pool, 4, 4.0));
        c.insert(key("a", 20), &block(&pool, 4, 5.0));
        // Evicted in recency order: 4 then 8 (0 was refreshed).
        assert!(c.get(&key("a", 4), 4).is_none());
        assert!(c.get(&key("a", 8), 4).is_none());
        assert!(c.get(&key("a", 0), 4).is_some());
        assert_eq!(c.stats().evictions, 2);
        assert_eq!(c.stats().entries, 4);
    }

    #[test]
    fn eviction_does_not_invalidate_outstanding_handles() {
        let pool = SlabPool::new(2, 4);
        let c = BlockCache::new(32); // exactly one block
        c.insert(key("a", 0), &block(&pool, 4, 7.0));
        let held = c.get(&key("a", 0), 4).expect("hit");
        c.insert(key("a", 4), &block(&pool, 4, 8.0)); // evicts the held one
        assert!(c.get(&key("a", 0), 4).is_none(), "evicted from the cache");
        // …but the handle a pipeline already streams from stays valid:
        // the slab returns to its pool only when the last holder drops.
        assert_eq!(held.as_slice(), &[7.0; 4][..]);
    }

    #[test]
    fn tail_window_is_charged_its_full_slab_capacity() {
        // A block published shorter than its slab (a tail window) pins
        // the whole slab: the budget must see the capacity, not the
        // published length — else short blocks hide most of their
        // allocation and residency overshoots the budget.
        let pool = SlabPool::new(2, 8); // 64-byte slabs
        let c = BlockCache::new(40); // fits a 4-elem payload, not a slab
        let mut bm = pool.take(4).unwrap(); // published 32, pins 64
        bm.as_mut_slice().fill(1.0);
        c.insert(key("a", 0), &bm.publish());
        assert_eq!(c.stats().entries, 0, "pinned bytes exceed the budget");
        // Under a slab-sized budget it caches — and the ledger carries
        // the pinned 64, not the published 32.
        let c = BlockCache::new(64);
        let mut bm = pool.take(4).unwrap();
        bm.as_mut_slice().fill(2.0);
        c.insert(key("a", 0), &bm.publish());
        assert_eq!(c.stats().entries, 1);
        assert_eq!(c.stats().bytes, 64);
        assert!(c.get(&key("a", 0), 4).is_some());
    }

    #[test]
    fn oversized_block_is_not_cached() {
        let pool = SlabPool::new(1, 4);
        let c = BlockCache::new(16); // < one 4-element block
        c.insert(key("a", 0), &block(&pool, 4, 0.0));
        let s = c.stats();
        assert_eq!(s.insertions, 0);
        assert_eq!(s.entries, 0);
        assert_eq!(s.bytes, 0);
    }

    #[test]
    fn zero_budget_disables() {
        let pool = SlabPool::new(1, 4);
        let c = BlockCache::new(0);
        c.insert(key("a", 0), &block(&pool, 4, 1.0));
        assert!(c.get(&key("a", 0), 4).is_none());
        assert_eq!(c.stats().entries, 0);
    }

    #[test]
    fn reinsert_replaces_without_leaking_bytes() {
        let pool = SlabPool::new(2, 4);
        let c = BlockCache::new(1 << 10);
        c.insert(key("a", 0), &block(&pool, 4, 1.0));
        c.insert(key("a", 0), &block(&pool, 4, 2.0));
        let s = c.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.bytes, 32);
        assert_eq!(c.get(&key("a", 0), 4).unwrap().as_slice(), &[2.0; 4][..]);
    }

    #[test]
    fn length_mismatch_is_a_miss() {
        let pool = SlabPool::new(1, 4);
        let c = BlockCache::new(1 << 10);
        c.insert(key("a", 0), &block(&pool, 4, 1.0));
        assert!(c.get(&key("a", 0), 3).is_none());
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn invalidate_removes_the_entry_but_not_held_handles() {
        let pool = SlabPool::new(2, 4);
        let c = BlockCache::new(1 << 10);
        c.insert(key("a", 0), &block(&pool, 4, 9.0));
        let held = c.get(&key("a", 0), 4).expect("hit");
        assert!(c.invalidate(&key("a", 0)), "entry was resident");
        assert!(!c.invalidate(&key("a", 0)), "second invalidate is a no-op");
        assert!(c.get(&key("a", 0), 4).is_none(), "no longer served");
        assert_eq!(c.stats().entries, 0);
        assert_eq!(c.stats().bytes, 0, "ledger released the pinned bytes");
        assert_eq!(held.as_slice(), &[9.0; 4][..], "held handle survives");
    }

    #[test]
    fn insert_shares_the_slab_instead_of_copying() {
        let pool = SlabPool::new(1, 4);
        let c = BlockCache::new(1 << 10);
        let b = block(&pool, 4, 3.0);
        c.insert(key("a", 0), &b);
        drop(b);
        // The cache's handle is the only holder now: the slab has NOT
        // returned to the pool (no copy was made on insert), and a take
        // must mint a replacement.
        assert_eq!(pool.stats().free, 0);
        pool.take(4).unwrap();
        assert_eq!(pool.stats().minted, 1);
    }

    #[test]
    fn shed_releases_lru_entries_down_to_the_target() {
        let pool = SlabPool::new(4, 4);
        let c = BlockCache::new(1 << 10);
        for i in 0..4u64 {
            c.insert(key("a", i * 4), &block(&pool, 4, i as f64));
        }
        assert_eq!(c.stats().bytes, 4 * 32);
        // Refresh entry 0 so it is the MRU survivor.
        assert!(c.get(&key("a", 0), 4).is_some());
        let released = c.shed(32);
        assert_eq!(released, 3 * 32);
        assert_eq!(c.stats().bytes, 32);
        assert!(c.get(&key("a", 0), 4).is_some(), "MRU survives a partial shed");
        // A held handle survives a full shed; the cache itself empties.
        let held = c.get(&key("a", 0), 4).expect("hit");
        assert_eq!(c.shed(0), 32);
        assert_eq!(c.stats().entries, 0);
        assert_eq!(held.as_slice(), &[0.0; 4][..]);
        assert_eq!(c.shed(0), 0, "shedding an empty cache is a no-op");
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let pool = SlabPool::new(1, 4);
        let c = Arc::new(BlockCache::new(1 << 20));
        c.insert(key("a", 0), &block(&pool, 4, 7.0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    let got = c.get(&key("a", 0), 4).expect("hit");
                    assert_eq!(got.as_slice(), &[7.0; 4][..]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.stats().hits, 4);
    }
}
