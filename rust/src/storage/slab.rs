//! Refcounted, cache-line-aligned block slabs — the zero-copy data plane.
//!
//! The paper's sustained-peak claim rests on the disk being the only data
//! mover; every host-side `memcpy` of a streamed block is overhead the
//! HDD analysis never budgeted for. This module makes blocks flow *by
//! reference* instead of by copy:
//!
//! ```text
//!   SlabPool::take ──▶ BlockMut (exclusive: the aio engine reads into it)
//!                          │ publish()            — immutable from here on
//!                          ▼
//!                       Block (Arc) ──clone──▶ BlockCache entry (zero copy)
//!                          │ slice()
//!                          ▼
//!                       BlockSlice ──▶ device lanes (one view per chunk)
//!                          ╰─ last handle drops ──▶ slab returns to pool
//! ```
//!
//! Aliasing is enforced by the type system: [`BlockMut`] is the only
//! writable stage and [`BlockMut::publish`] consumes it, so once a
//! [`Block`] exists no `&mut` path to the slab remains — a published
//! block cannot be mutated while the cache or a lane holds a view
//! (`tests/zero_copy.rs` exercises the runtime face of this via
//! [`Block::try_unpublish`]).
//!
//! Pool discipline, in the spirit of [`crate::coordinator::pool::BufPool`]:
//! the pool pre-faults `retain` slabs and recycles them through a drop
//! hook, so a stream that releases its blocks as fast as it takes them
//! allocates nothing. Unlike `BufPool` it may *mint* an extra slab when
//! the free list is empty — which happens only while published blocks
//! are retained elsewhere: by the shared [`BlockCache`], by lane views
//! still in flight past the read-ahead, or inside a dying engine. The
//! demand is structurally bounded (read-ahead + device-buffer depth),
//! never open-ended, and excess returns beyond `retain` are freed, so
//! residency converges back to the budget. [`SlabStats`] exposes the
//! mint/recycle counters the tests pin this down with.
//!
//! [`BlockCache`]: crate::storage::BlockCache

use crate::error::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

/// Cache-line size the slabs align to (bytes).
pub const SLAB_ALIGN: usize = 64;
const ALIGN_ELEMS: usize = SLAB_ALIGN / std::mem::size_of::<f64>();

/// One aligned allocation. The backing `Vec` is over-allocated by one
/// cache line and never grown, so the aligned offset computed at
/// construction stays valid for the slab's whole life.
#[derive(Debug)]
struct Slab {
    data: Vec<f64>,
    /// Element offset of the first 64-byte-aligned f64.
    off: usize,
    /// Usable aligned capacity in elements.
    cap: usize,
}

impl Slab {
    fn new(cap: usize) -> Slab {
        let data = vec![0.0f64; cap + ALIGN_ELEMS];
        let addr = data.as_ptr() as usize;
        let off = (SLAB_ALIGN - addr % SLAB_ALIGN) % SLAB_ALIGN / std::mem::size_of::<f64>();
        Slab { data, off, cap }
    }

    fn slice(&self, len: usize) -> &[f64] {
        debug_assert!(len <= self.cap);
        &self.data[self.off..self.off + len]
    }

    fn slice_mut(&mut self, len: usize) -> &mut [f64] {
        debug_assert!(len <= self.cap);
        &mut self.data[self.off..self.off + len]
    }
}

/// Pool counters (monotone, plus the current free count).
#[derive(Debug, Clone, Copy, Default)]
pub struct SlabStats {
    /// Slabs allocated beyond the pre-faulted set (free list was empty —
    /// e.g. the cache retained a published block past the segment).
    pub minted: u64,
    /// Slabs returned to the free list by a released block.
    pub recycled: u64,
    /// Returns that found the free list already at `retain` and freed
    /// the slab instead (residency converging back to the budget).
    pub dropped: u64,
    /// Slabs currently on the free list.
    pub free: usize,
}

#[derive(Debug, Default)]
struct StatsCells {
    minted: AtomicU64,
    recycled: AtomicU64,
    dropped: AtomicU64,
}

#[derive(Debug)]
struct PoolShared {
    free: Mutex<Vec<Slab>>,
    /// Free slabs retained for reuse (the tuned host-buffer budget).
    retain: usize,
    /// Aligned capacity of every slab (elements).
    cap_elems: usize,
    stats: StatsCells,
}

impl PoolShared {
    /// Return a slab to the free list, or free it when already full.
    fn recycle(&self, slab: Slab) {
        let mut free = self.free.lock().expect("slab pool lock poisoned");
        if free.len() < self.retain {
            free.push(slab);
            self.stats.recycled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// A pool of same-capacity aligned slabs recycled through the stream
/// (see module docs for the discipline).
#[derive(Debug)]
pub struct SlabPool {
    shared: Arc<PoolShared>,
}

impl SlabPool {
    /// `retain` slabs of `cap_elems` aligned f64 elements, pre-faulted.
    pub fn new(retain: usize, cap_elems: usize) -> SlabPool {
        let free = (0..retain).map(|_| Slab::new(cap_elems)).collect();
        SlabPool {
            shared: Arc::new(PoolShared {
                free: Mutex::new(free),
                retain,
                cap_elems,
                stats: StatsCells::default(),
            }),
        }
    }

    /// The pool's retained-slab budget (the read-ahead sizing knob).
    pub fn target(&self) -> usize {
        self.shared.retain
    }

    /// Aligned capacity of each slab in elements.
    pub fn cap_elems(&self) -> usize {
        self.shared.cap_elems
    }

    /// Take a writable slab for `len` elements. Reuses a free slab when
    /// one exists, mints a replacement otherwise (the free list only
    /// runs dry while published blocks are retained elsewhere — the
    /// shared cache, lane views in flight, or a dying engine).
    pub fn take(&self, len: usize) -> Result<BlockMut> {
        if len == 0 || len > self.shared.cap_elems {
            return Err(Error::Config(format!(
                "slab take of {len} elements outside pool capacity {}",
                self.shared.cap_elems
            )));
        }
        let slab = self.shared.free.lock().expect("slab pool lock poisoned").pop();
        let slab = match slab {
            Some(s) => s,
            None => {
                self.shared.stats.minted.fetch_add(1, Ordering::Relaxed);
                Slab::new(self.shared.cap_elems)
            }
        };
        let rec = Recycler {
            slab: Some(slab),
            pool: Arc::downgrade(&self.shared),
            checksum: AtomicU64::new(0),
        };
        Ok(BlockMut { rec, len })
    }

    pub fn stats(&self) -> SlabStats {
        SlabStats {
            minted: self.shared.stats.minted.load(Ordering::Relaxed),
            recycled: self.shared.stats.recycled.load(Ordering::Relaxed),
            dropped: self.shared.stats.dropped.load(Ordering::Relaxed),
            free: self.shared.free.lock().expect("slab pool lock poisoned").len(),
        }
    }
}

/// Drop hook that returns the slab to its pool — however the holder
/// dies. A lane dropping its last view, the cache evicting an entry,
/// and an aio engine thread unwinding with a request in flight all
/// funnel through here, so no path can leak a slab or mint a
/// replacement for one that still exists.
#[derive(Debug)]
struct Recycler {
    slab: Option<Slab>,
    /// Weak: blocks may outlive their engine's pool (the shared cache
    /// does this by design); the orphaned slab is then simply freed.
    pool: Weak<PoolShared>,
    /// Integrity checksum of the payload, recorded at read time by the
    /// aio engine ([`crate::storage::fault::checksum`]); 0 = absent.
    /// Lives on the recycler so every clone of a published block — the
    /// cache entry, the lane views — shares the one value, and a fresh
    /// `take()` starts clean.
    checksum: AtomicU64,
}

impl Drop for Recycler {
    fn drop(&mut self) {
        if let (Some(slab), Some(pool)) = (self.slab.take(), self.pool.upgrade()) {
            pool.recycle(slab);
        }
    }
}

/// The exclusive, writable stage of a block's life: the aio engine
/// reads disk bytes straight into it. [`BlockMut::publish`] consumes it
/// into an immutable [`Block`]; dropping it unpublished (error paths,
/// a dying engine) returns the slab to the pool.
#[derive(Debug)]
pub struct BlockMut {
    rec: Recycler,
    len: usize,
}

impl BlockMut {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[f64] {
        self.rec.slab.as_ref().expect("slab present until drop").slice(self.len)
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        self.rec.slab.as_mut().expect("slab present until drop").slice_mut(self.len)
    }

    /// Record the payload's integrity checksum (the aio engine calls
    /// this right after the disk bytes land; 0 means "absent").
    pub fn set_checksum(&self, ck: u64) {
        self.rec.checksum.store(ck, Ordering::Release);
    }

    /// Freeze the slab: from here on only shared `&[f64]` access exists.
    pub fn publish(self) -> Block {
        let len = self.len;
        Block { rec: Arc::new(self.rec), len }
    }
}

/// A published, immutable, refcounted block. Cloning is an `Arc` clone;
/// the slab returns to its pool when the last handle (cache entry, lane
/// view, coordinator) drops.
#[derive(Debug, Clone)]
pub struct Block {
    rec: Arc<Recycler>,
    len: usize,
}

impl Block {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of the published payload (the logical block).
    pub fn bytes(&self) -> u64 {
        (self.len * std::mem::size_of::<f64>()) as u64
    }

    /// Bytes this handle actually pins: the slab's full usable capacity,
    /// which can exceed [`Block::bytes`] for a tail window published
    /// short. Anything metering *residency* (the cache's byte budget)
    /// must charge this, or a retained short block hides most of its
    /// allocation from the ledger.
    pub fn resident_bytes(&self) -> u64 {
        let slab = self.rec.slab.as_ref().expect("slab present until drop");
        (slab.cap * std::mem::size_of::<f64>()) as u64
    }

    pub fn as_slice(&self) -> &[f64] {
        self.rec.slab.as_ref().expect("slab present until drop").slice(self.len)
    }

    /// A borrowed view of `len` elements starting at `off` — what the
    /// coordinator hands each device lane instead of a copied chunk.
    pub fn slice(&self, off: usize, len: usize) -> BlockSlice {
        assert!(
            off + len <= self.len,
            "block slice {off}+{len} out of bounds (block has {})",
            self.len
        );
        BlockSlice { block: self.clone(), off, len }
    }

    /// The checksum recorded at read time (0 = none was recorded, e.g.
    /// integrity checking was off or the block never came from disk).
    pub fn checksum(&self) -> u64 {
        self.rec.checksum.load(Ordering::Acquire)
    }

    /// Re-verify the payload against its read-time checksum: false only
    /// when a checksum exists and no longer matches the bytes — the
    /// "corruption detected, re-read it" signal. Blocks without a
    /// recorded checksum verify trivially.
    pub fn integrity_ok(&self) -> bool {
        let want = self.checksum();
        want == 0 || crate::storage::fault::checksum(self.as_slice()) == want
    }

    /// Reclaim exclusive (mutable) access — succeeds only when this is
    /// the *last* handle. While the cache or any lane still holds the
    /// block, mutation is impossible: this is the runtime face of the
    /// publish-freeze guarantee.
    pub fn try_unpublish(self) -> std::result::Result<BlockMut, Block> {
        let len = self.len;
        match Arc::try_unwrap(self.rec) {
            Ok(rec) => Ok(BlockMut { rec, len }),
            Err(rec) => Err(Block { rec, len }),
        }
    }
}

/// A `(offset, width)` view into a published [`Block`] — the per-lane
/// chunk of the zero-copy plane. Holding one keeps the whole slab alive.
#[derive(Debug, Clone)]
pub struct BlockSlice {
    block: Block,
    off: usize,
    len: usize,
}

impl BlockSlice {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.block.as_slice()[self.off..self.off + self.len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(pool: &SlabPool, len: usize, v: f64) -> Block {
        let mut bm = pool.take(len).unwrap();
        bm.as_mut_slice().fill(v);
        bm.publish()
    }

    #[test]
    fn slabs_are_cache_line_aligned() {
        for cap in [1usize, 7, 8, 1024, 4093] {
            let pool = SlabPool::new(2, cap);
            let bm = pool.take(cap).unwrap();
            let addr = bm.as_slice().as_ptr() as usize;
            assert_eq!(addr % SLAB_ALIGN, 0, "cap {cap}: {addr:#x}");
        }
    }

    #[test]
    fn steady_state_reuse_mints_nothing() {
        let pool = SlabPool::new(3, 64);
        for round in 0..10 {
            let blocks: Vec<Block> = (0..3).map(|i| filled(&pool, 64, i as f64)).collect();
            for (i, b) in blocks.iter().enumerate() {
                assert_eq!(b.as_slice()[0], i as f64);
            }
            drop(blocks);
            let s = pool.stats();
            assert_eq!(s.minted, 0, "round {round}");
            assert_eq!(s.free, 3);
        }
        assert_eq!(pool.stats().recycled, 30);
    }

    #[test]
    fn retained_block_mints_replacement_then_converges() {
        let pool = SlabPool::new(1, 16);
        let held = filled(&pool, 16, 1.0); // the "cache" keeps this one
        let b2 = filled(&pool, 16, 2.0); // free list empty → mint
        assert_eq!(pool.stats().minted, 1);
        drop(b2); // recycled: free list back at retain
        assert_eq!(pool.stats().free, 1);
        drop(held); // free list full → freed, not hoarded
        let s = pool.stats();
        assert_eq!(s.free, 1);
        assert_eq!(s.dropped, 1);
    }

    #[test]
    fn views_share_the_slab_and_keep_it_alive() {
        let pool = SlabPool::new(2, 32);
        let mut bm = pool.take(32).unwrap();
        for (i, v) in bm.as_mut_slice().iter_mut().enumerate() {
            *v = i as f64;
        }
        let block = bm.publish();
        let a = block.slice(0, 16);
        let b = block.slice(16, 16);
        drop(block); // views alone keep the slab resident
        assert_eq!(a.as_slice()[3], 3.0);
        assert_eq!(b.as_slice()[0], 16.0);
        assert_eq!(pool.stats().free, 1, "slab still out while views live");
        drop((a, b));
        assert_eq!(pool.stats().free, 2, "recycled after the last view");
        assert_eq!(pool.stats().recycled, 1);
    }

    #[test]
    fn published_block_cannot_be_mutated_while_viewed() {
        let pool = SlabPool::new(1, 8);
        let block = filled(&pool, 8, 7.0);
        let view = block.slice(0, 4);
        // A second handle exists → unpublish (the only path back to
        // &mut) must refuse.
        let block = block.try_unpublish().expect_err("view still alive");
        drop(view);
        // Sole handle → exclusive access again.
        let mut bm = block.try_unpublish().expect("last handle");
        bm.as_mut_slice()[0] = 9.0;
        assert_eq!(bm.as_slice()[0], 9.0);
    }

    #[test]
    fn blocks_outlive_their_pool() {
        let pool = SlabPool::new(1, 8);
        let block = filled(&pool, 8, 3.5);
        drop(pool); // e.g. engine torn down while the cache holds the block
        assert_eq!(block.as_slice(), &[3.5; 8][..]);
        drop(block); // orphaned slab is freed, no panic
    }

    #[test]
    fn take_rejects_oversize_and_zero() {
        let pool = SlabPool::new(1, 8);
        assert!(pool.take(9).is_err());
        assert!(pool.take(0).is_err());
        assert!(pool.take(8).is_ok());
    }

    #[test]
    fn checksum_travels_with_the_block_and_detects_corruption() {
        let pool = SlabPool::new(1, 16);
        let mut bm = pool.take(16).unwrap();
        bm.as_mut_slice().fill(2.5);
        // No checksum recorded → verifies trivially (integrity off).
        let block = bm.publish();
        assert_eq!(block.checksum(), 0);
        assert!(block.integrity_ok());
        // Record one, corrupt the payload through unpublish, re-verify.
        let mut bm = block.try_unpublish().unwrap();
        let ck = crate::storage::fault::checksum(bm.as_slice());
        bm.set_checksum(ck);
        let block = bm.publish();
        let clone = block.clone(); // the "cache entry"
        assert_eq!(clone.checksum(), ck, "clones share the recorded checksum");
        assert!(block.integrity_ok() && clone.integrity_ok());
        drop(block);
        let mut bm = clone.try_unpublish().unwrap();
        bm.as_mut_slice()[7] = f64::from_bits(bm.as_slice()[7].to_bits() ^ 1);
        let block = bm.publish();
        assert!(!block.integrity_ok(), "flipped bit must fail verification");
        // A fresh take() of the recycled slab starts without a checksum.
        drop(block);
        let fresh = pool.take(16).unwrap().publish();
        assert_eq!(fresh.checksum(), 0);
    }

    #[test]
    fn blocks_cross_threads() {
        let pool = SlabPool::new(2, 128);
        let block = filled(&pool, 128, 4.0);
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let view = block.slice(i * 32, 32);
                std::thread::spawn(move || view.as_slice().iter().sum::<f64>())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 32.0 * 4.0);
        }
        drop(block);
        assert_eq!(pool.stats().free, 2);
    }
}
