//! XRD — the on-disk format for streamed GWAS data.
//!
//! The paper streams `X_R` (up to 14 TB) from HDD in fixed-size column
//! blocks, and writes the results `r` back out. XRD is the minimal format
//! that makes that access pattern exact:
//!
//! ```text
//! ┌──────────────────────────────────────────────┐
//! │ header (64 bytes)                            │
//! │   magic  "XRD1"            u32 (LE bytes)    │
//! │   version                  u32               │
//! │   rows (n)                 u64               │
//! │   cols (m)                 u64               │
//! │   block_cols               u64               │
//! │   seed                     u64               │
//! │   header_crc               u64               │
//! │   reserved                 u64×2             │
//! ├──────────────────────────────────────────────┤
//! │ block 0: rows×block_cols f64 LE, col-major   │
//! │ block 1: …                                   │
//! │ block k-1: possibly fewer columns (tail)     │
//! └──────────────────────────────────────────────┘
//! ```
//!
//! Blocks are byte-images of column-major [`Matrix`] buffers, so a read is
//! one contiguous `pread` straight into the destination buffer — the same
//! property the paper's `aio_read` of `X_R` blocks relies on.

use crate::error::{Error, Result};
use std::io::{Read, Write};

/// Magic bytes at offset 0.
pub const MAGIC: [u8; 4] = *b"XRD1";
/// Current format version.
pub const VERSION: u32 = 2;
/// Serialized header size in bytes.
pub const HEADER_BYTES: usize = 64;

/// On-disk element type. The paper's footnote 3 asks whether single
/// precision suffices for genotype storage ("the sizes should be
/// halved"); XRD v2 supports both. Genotypes are exact small integers in
/// f32, so `F32` storage loses nothing for `X_R` while halving disk and
/// I/O bandwidth; compute always widens to f64.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F64,
    F32,
}

impl Dtype {
    pub fn bytes(&self) -> u64 {
        match self {
            Dtype::F64 => 8,
            Dtype::F32 => 4,
        }
    }

    fn code(&self) -> u32 {
        match self {
            Dtype::F64 => 1,
            Dtype::F32 => 2,
        }
    }

    fn from_code(c: u32) -> Result<Dtype> {
        match c {
            1 => Ok(Dtype::F64),
            2 => Ok(Dtype::F32),
            other => Err(Error::format(format!("unknown XRD dtype code {other}"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Dtype::F64 => "f64",
            Dtype::F32 => "f32",
        }
    }
}

/// Parsed XRD header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub rows: u64,
    pub cols: u64,
    pub block_cols: u64,
    /// RNG seed the dataset was generated from (0 for imported data).
    pub seed: u64,
    /// On-disk element type (in-memory is always f64).
    pub dtype: Dtype,
}

impl Header {
    pub fn new(rows: u64, cols: u64, block_cols: u64, seed: u64) -> Result<Self> {
        Self::with_dtype(rows, cols, block_cols, seed, Dtype::F64)
    }

    pub fn with_dtype(rows: u64, cols: u64, block_cols: u64, seed: u64, dtype: Dtype) -> Result<Self> {
        if rows == 0 || cols == 0 {
            return Err(Error::format(format!("XRD dims must be positive ({rows}x{cols})")));
        }
        if block_cols == 0 || block_cols > cols {
            return Err(Error::format(format!(
                "block_cols {block_cols} must be in 1..={cols}"
            )));
        }
        Ok(Header { rows, cols, block_cols, seed, dtype })
    }

    /// Number of blocks, last one possibly partial.
    pub fn block_count(&self) -> u64 {
        self.cols.div_ceil(self.block_cols)
    }

    /// Columns in block `b`.
    pub fn cols_in_block(&self, b: u64) -> u64 {
        debug_assert!(b < self.block_count());
        if b + 1 == self.block_count() {
            self.cols - b * self.block_cols
        } else {
            self.block_cols
        }
    }

    /// Byte offset of block `b`'s first element.
    pub fn block_offset(&self, b: u64) -> u64 {
        HEADER_BYTES as u64 + b * self.block_cols * self.rows * self.dtype.bytes()
    }

    /// Byte length of block `b`.
    pub fn block_bytes(&self, b: u64) -> u64 {
        self.cols_in_block(b) * self.rows * self.dtype.bytes()
    }

    /// Total file size implied by the header.
    pub fn file_bytes(&self) -> u64 {
        HEADER_BYTES as u64 + self.rows * self.cols * self.dtype.bytes()
    }

    /// A cheap integrity word over the header fields (not cryptographic;
    /// catches truncation and version drift).
    fn crc(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a basis
        for v in [
            self.rows,
            self.cols,
            self.block_cols,
            self.seed,
            VERSION as u64,
            self.dtype.code() as u64,
        ] {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        }
        h
    }

    /// Serialize to the fixed 64-byte header image.
    pub fn to_bytes(&self) -> [u8; HEADER_BYTES] {
        let mut out = [0u8; HEADER_BYTES];
        out[0..4].copy_from_slice(&MAGIC);
        out[4..8].copy_from_slice(&VERSION.to_le_bytes());
        out[8..16].copy_from_slice(&self.rows.to_le_bytes());
        out[16..24].copy_from_slice(&self.cols.to_le_bytes());
        out[24..32].copy_from_slice(&self.block_cols.to_le_bytes());
        out[32..40].copy_from_slice(&self.seed.to_le_bytes());
        out[40..48].copy_from_slice(&self.crc().to_le_bytes());
        out[48..52].copy_from_slice(&self.dtype.code().to_le_bytes());
        out
    }

    /// Parse and validate a header image.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        if buf.len() < HEADER_BYTES {
            return Err(Error::format(format!("XRD header truncated: {} bytes", buf.len())));
        }
        if buf[0..4] != MAGIC {
            return Err(Error::format("bad XRD magic".to_string()));
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(Error::format(format!("unsupported XRD version {version}")));
        }
        let rows = u64::from_le_bytes(buf[8..16].try_into().unwrap());
        let cols = u64::from_le_bytes(buf[16..24].try_into().unwrap());
        let block_cols = u64::from_le_bytes(buf[24..32].try_into().unwrap());
        let seed = u64::from_le_bytes(buf[32..40].try_into().unwrap());
        let crc = u64::from_le_bytes(buf[40..48].try_into().unwrap());
        let dtype = Dtype::from_code(u32::from_le_bytes(buf[48..52].try_into().unwrap()))?;
        let h = Header::with_dtype(rows, cols, block_cols, seed, dtype)?;
        if h.crc() != crc {
            return Err(Error::format("XRD header checksum mismatch".to_string()));
        }
        Ok(h)
    }

    /// Read a header from the start of a stream.
    pub fn read_from(r: &mut impl Read) -> Result<Self> {
        let mut buf = [0u8; HEADER_BYTES];
        r.read_exact(&mut buf).map_err(|e| Error::io("reading XRD header", e))?;
        Self::from_bytes(&buf)
    }

    /// Write the header to a stream.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.to_bytes()).map_err(|e| Error::io("writing XRD header", e))
    }
}

/// View of f64s as little-endian bytes (all supported platforms here are
/// LE; asserted at compile time below).
pub fn f64s_as_bytes(v: &[f64]) -> &[u8] {
    // SAFETY: f64 has no invalid bit patterns and we only reinterpret POD.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 8) }
}

/// Mutable byte view over an f64 buffer (read target).
pub fn f64s_as_bytes_mut(v: &mut [f64]) -> &mut [u8] {
    // SAFETY: as above; every byte pattern is a valid f64.
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, v.len() * 8) }
}

/// View of f32s as little-endian bytes (for Dtype::F32 storage).
pub fn f32s_as_bytes(v: &[f32]) -> &[u8] {
    // SAFETY: POD reinterpretation as above.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Mutable byte view over an f32 buffer.
pub fn f32s_as_bytes_mut(v: &mut [f32]) -> &mut [u8] {
    // SAFETY: every byte pattern is a valid f32.
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, v.len() * 4) }
}

#[cfg(target_endian = "big")]
compile_error!("XRD assumes little-endian storage");

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_header() {
        let h = Header::new(10_000, 190_000, 5_000, 42).unwrap();
        let bytes = h.to_bytes();
        let back = Header::from_bytes(&bytes).unwrap();
        assert_eq!(h, back);
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let h = Header::new(4, 4, 2, 0).unwrap();
        let mut b = h.to_bytes();
        b[0] = b'Y';
        assert!(Header::from_bytes(&b).is_err());
        let mut b2 = h.to_bytes();
        b2[4] = 99;
        assert!(Header::from_bytes(&b2).is_err());
    }

    #[test]
    fn rejects_corrupt_crc() {
        let h = Header::new(4, 4, 2, 0).unwrap();
        let mut b = h.to_bytes();
        b[9] ^= 0xFF; // flip a bit in `rows`
        assert!(Header::from_bytes(&b).is_err());
    }

    #[test]
    fn rejects_bad_dims() {
        assert!(Header::new(0, 4, 2, 0).is_err());
        assert!(Header::new(4, 0, 2, 0).is_err());
        assert!(Header::new(4, 4, 0, 0).is_err());
        assert!(Header::new(4, 4, 5, 0).is_err()); // block bigger than cols
    }

    #[test]
    fn block_geometry_with_tail() {
        let h = Header::new(100, 10, 3, 0).unwrap(); // blocks: 3,3,3,1
        assert_eq!(h.block_count(), 4);
        assert_eq!(h.cols_in_block(0), 3);
        assert_eq!(h.cols_in_block(3), 1);
        assert_eq!(h.block_offset(0), 64);
        assert_eq!(h.block_offset(1), 64 + 3 * 100 * 8);
        assert_eq!(h.block_bytes(3), 100 * 8);
        assert_eq!(h.file_bytes(), 64 + 1000 * 8);
    }

    #[test]
    fn exact_blocks_no_tail() {
        let h = Header::new(8, 9, 3, 0).unwrap();
        assert_eq!(h.block_count(), 3);
        for b in 0..3 {
            assert_eq!(h.cols_in_block(b), 3);
        }
    }

    #[test]
    fn byte_views_roundtrip() {
        let v = vec![1.5f64, -2.25, 0.0];
        let bytes = f64s_as_bytes(&v).to_vec();
        let mut back = vec![0.0f64; 3];
        f64s_as_bytes_mut(&mut back).copy_from_slice(&bytes);
        assert_eq!(v, back);
    }
}
