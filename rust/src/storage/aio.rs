//! The asynchronous I/O engine — the paper's `aio_read` / `aio_wait` /
//! `aio_write` primitives (Listing 1.2 lines 6–9, Listing 1.3 lines
//! 12/15/23–24).
//!
//! POSIX `aio` (what OOC-HP-GWAS used) is emulated with a dedicated I/O
//! thread per file and completion channels: submission returns an
//! [`AioHandle`] immediately; `wait()` blocks until the positioned
//! read/write finished and hands the buffer back. Buffers travel *through*
//! the engine (moved, never copied), so the steady-state pipeline performs
//! zero allocation — the same discipline the paper's buffer rotation
//! enforces.
//!
//! One engine per file keeps requests FIFO per device, which is both what
//! `aio` on a single HDD gives you and what makes the sequential streaming
//! pattern of the paper (`b+2` read while `b` computes) predictable.

use crate::error::{Error, Result};
use crate::storage::xrd::XrdFile;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// A submitted I/O operation; `wait()` yields the buffer back.
pub struct AioHandle {
    rx: Receiver<(Vec<f64>, Result<()>)>,
    /// Element count of the submitted buffer. If the engine dies before
    /// completing, the original buffer is lost inside the dead thread —
    /// a replacement of this size keeps the caller's pool invariant
    /// (fixed buffer count, fixed capacity) intact through the error.
    capacity: usize,
}

impl AioHandle {
    /// A handle that is already complete — e.g. a block served from the
    /// shared [`BlockCache`](crate::storage::BlockCache) with no disk
    /// read issued. Lets cache hits flow through the same `aio_wait`
    /// plumbing as real reads.
    pub fn ready(buf: Vec<f64>, res: Result<()>) -> AioHandle {
        let (tx, rx) = channel();
        let capacity = buf.len();
        let _ = tx.send((buf, res));
        AioHandle { rx, capacity }
    }

    /// Replacement buffer for a request lost inside a dead engine.
    fn lost(&self) -> (Vec<f64>, Result<()>) {
        (
            vec![0.0; self.capacity],
            Err(Error::Pipeline("aio engine died before completing request".into())),
        )
    }

    /// Block until the operation completes. Returns the buffer (always —
    /// also on error or engine death, so callers can keep their pool
    /// intact) plus status.
    pub fn wait(self) -> (Vec<f64>, Result<()>) {
        match self.rx.recv() {
            Ok(pair) => pair,
            Err(_) => self.lost(),
        }
    }

    /// Non-blocking completion attempt: `Ok` with the result if done,
    /// `Err(self)` (handle returned intact) if still in flight.
    pub fn try_wait(self) -> std::result::Result<(Vec<f64>, Result<()>), AioHandle> {
        match self.rx.try_recv() {
            Ok(pair) => Ok(pair),
            Err(std::sync::mpsc::TryRecvError::Empty) => Err(self),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Ok(self.lost()),
        }
    }
}

enum Req {
    Read { block: u64, buf: Vec<f64>, done: Sender<(Vec<f64>, Result<()>)> },
    Write { block: u64, buf: Vec<f64>, done: Sender<(Vec<f64>, Result<()>)> },
    ReadCols { col0: u64, ncols: u64, buf: Vec<f64>, done: Sender<(Vec<f64>, Result<()>)> },
    WriteCols { col0: u64, ncols: u64, buf: Vec<f64>, done: Sender<(Vec<f64>, Result<()>)> },
    Sync { done: Sender<(Vec<f64>, Result<()>)> },
    Shutdown,
}

/// Async engine over one [`XrdFile`].
pub struct AioEngine {
    tx: Option<Sender<Req>>,
    worker: Option<JoinHandle<()>>,
}

impl AioEngine {
    /// Spawn the I/O thread owning `file`.
    pub fn new(file: XrdFile) -> Self {
        let (tx, rx) = channel::<Req>();
        let worker = std::thread::Builder::new()
            .name("cugwas-aio".into())
            .spawn(move || {
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Read { block, mut buf, done } => {
                            let res = file.read_block_into(block, &mut buf);
                            let _ = done.send((buf, res));
                        }
                        Req::Write { block, buf, done } => {
                            let res = file.write_block(block, &buf);
                            let _ = done.send((buf, res));
                        }
                        Req::ReadCols { col0, ncols, mut buf, done } => {
                            let res = file.read_cols_into(col0, ncols, &mut buf);
                            let _ = done.send((buf, res));
                        }
                        Req::WriteCols { col0, ncols, buf, done } => {
                            let res = file.write_cols(col0, ncols, &buf);
                            let _ = done.send((buf, res));
                        }
                        Req::Sync { done } => {
                            let _ = done.send((Vec::new(), file.sync()));
                        }
                        Req::Shutdown => break,
                    }
                }
            })
            .expect("spawning aio thread");
        AioEngine { tx: Some(tx), worker: Some(worker) }
    }

    fn submit(&self, req: Req) {
        self.tx
            .as_ref()
            .expect("engine already shut down")
            .send(req)
            .expect("aio thread alive");
    }

    /// `aio_read`: fill `buf` from block `b` asynchronously.
    pub fn read(&self, block: u64, buf: Vec<f64>) -> AioHandle {
        let (done, rx) = channel();
        let capacity = buf.len();
        self.submit(Req::Read { block, buf, done });
        AioHandle { rx, capacity }
    }

    /// `aio_write`: write `buf` to block `b` asynchronously.
    pub fn write(&self, block: u64, buf: Vec<f64>) -> AioHandle {
        let (done, rx) = channel();
        let capacity = buf.len();
        self.submit(Req::Write { block, buf, done });
        AioHandle { rx, capacity }
    }

    /// `aio_read` of an arbitrary column range (block-size-agnostic).
    pub fn read_cols(&self, col0: u64, ncols: u64, buf: Vec<f64>) -> AioHandle {
        let (done, rx) = channel();
        let capacity = buf.len();
        self.submit(Req::ReadCols { col0, ncols, buf, done });
        AioHandle { rx, capacity }
    }

    /// `aio_write` of an arbitrary column range.
    pub fn write_cols(&self, col0: u64, ncols: u64, buf: Vec<f64>) -> AioHandle {
        let (done, rx) = channel();
        let capacity = buf.len();
        self.submit(Req::WriteCols { col0, ncols, buf, done });
        AioHandle { rx, capacity }
    }

    /// Queue a data sync behind all submitted operations.
    pub fn sync(&self) -> AioHandle {
        let (done, rx) = channel();
        self.submit(Req::Sync { done });
        AioHandle { rx, capacity: 0 }
    }
}

impl Drop for AioEngine {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Req::Shutdown);
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::format::Header;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cugwas_aio_{}_{tag}.xrd", std::process::id()))
    }

    #[test]
    fn async_roundtrip_preserves_data_and_buffers() {
        let p = tmpfile("rt");
        let h = Header::new(8, 9, 3, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        // Write all blocks asynchronously.
        let mut handles = Vec::new();
        for b in 0..3u64 {
            let data: Vec<f64> = (0..24).map(|i| b as f64 * 100.0 + i as f64).collect();
            handles.push(eng.write(b, data));
        }
        for hd in handles {
            let (buf, res) = hd.wait();
            res.unwrap();
            assert_eq!(buf.len(), 24); // buffer comes back for reuse
        }
        // Read them back out of order.
        for &b in &[2u64, 0, 1] {
            let (buf, res) = eng.read(b, vec![0.0; 24]).wait();
            res.unwrap();
            assert_eq!(buf[0], b as f64 * 100.0);
        }
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn overlapping_submissions_complete_in_order() {
        let p = tmpfile("order");
        let h = Header::new(16, 20, 5, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        let w: Vec<AioHandle> =
            (0..4).map(|b| eng.write(b, vec![b as f64; 80])).collect();
        // Submit dependent reads before waiting on the writes: FIFO per
        // engine guarantees the reads see the written data.
        let r: Vec<AioHandle> = (0..4).map(|b| eng.read(b, vec![0.0; 80])).collect();
        for hd in w {
            hd.wait().1.unwrap();
        }
        for (b, hd) in r.into_iter().enumerate() {
            let (buf, res) = hd.wait();
            res.unwrap();
            assert!(buf.iter().all(|&v| v == b as f64));
        }
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn error_surfaces_but_buffer_survives() {
        let p = tmpfile("err");
        let h = Header::new(4, 4, 2, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        let (buf, res) = eng.read(7, vec![0.0; 8]).wait(); // out of range
        assert!(res.is_err());
        assert_eq!(buf.len(), 8);
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn dead_engine_returns_correctly_sized_buffer() {
        // Simulate engine death with a request in flight: the completion
        // sender is gone without ever delivering. The caller must get a
        // buffer of the submitted size back, not an empty Vec — otherwise
        // the pool would silently shrink its capacity on error.
        let (tx, rx) = channel::<(Vec<f64>, Result<()>)>();
        drop(tx);
        let h = AioHandle { rx, capacity: 24 };
        let (buf, res) = h.wait();
        assert!(res.is_err());
        assert_eq!(buf.len(), 24);

        let (tx, rx) = channel::<(Vec<f64>, Result<()>)>();
        drop(tx);
        let h = AioHandle { rx, capacity: 7 };
        let (buf, res) = h.try_wait().expect("disconnected resolves immediately");
        assert!(res.is_err());
        assert_eq!(buf.len(), 7);
    }

    #[test]
    fn ready_handle_completes_immediately() {
        let h = AioHandle::ready(vec![3.0; 5], Ok(()));
        let (buf, res) = h.wait();
        res.unwrap();
        assert_eq!(buf, vec![3.0; 5]);
        // try_wait path too.
        let h = AioHandle::ready(vec![1.0; 2], Ok(()));
        let (buf, _) = h.try_wait().expect("ready");
        assert_eq!(buf.len(), 2);
    }

    #[test]
    fn sync_completes() {
        let p = tmpfile("sync");
        let h = Header::new(4, 4, 2, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        eng.write(0, vec![1.0; 8]).wait().1.unwrap();
        eng.sync().wait().1.unwrap();
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }
}
