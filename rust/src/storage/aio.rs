//! The asynchronous I/O engine — the paper's `aio_read` / `aio_wait` /
//! `aio_write` primitives (Listing 1.2 lines 6–9, Listing 1.3 lines
//! 12/15/23–24).
//!
//! POSIX `aio` (what OOC-HP-GWAS used) is emulated with a dedicated I/O
//! thread per file and completion channels: submission returns an
//! [`AioHandle`] immediately; `wait()` blocks until the positioned
//! read/write finished and hands the buffer back. Buffers travel *through*
//! the engine (moved, never copied), so the steady-state pipeline performs
//! zero allocation — the same discipline the paper's buffer rotation
//! enforces. Block reads go one step further: [`AioEngine::read_cols_slab`]
//! reads straight into an aligned [`BlockMut`] slab that, once published,
//! the cache and the device lanes share by reference (the zero-copy data
//! plane — see [`crate::storage::slab`]).
//!
//! One engine per file keeps requests FIFO per device, which is both what
//! `aio` on a single HDD gives you and what makes the sequential streaming
//! pattern of the paper (`b+2` read while `b` computes) predictable.

use crate::error::{Error, Result};
use crate::storage::fault;
use crate::storage::slab::BlockMut;
use crate::storage::xrd::XrdFile;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A submitted I/O operation; `wait()` yields the buffer back.
pub struct AioHandle {
    rx: Receiver<(Vec<f64>, Result<()>)>,
}

/// Engine death loses the request's buffer inside the dead thread.
/// Deliberately NOT replaced with a zeroed buffer of the right size:
/// that is exactly the kind of silently-plausible data a caller might
/// compute on. An empty buffer plus a hard `Error::Io` forces every
/// caller to notice (pools are rebuilt on teardown, so the lost
/// capacity never leaks into a healthy pipeline).
fn lost() -> (Vec<f64>, Result<()>) {
    (
        Vec::new(),
        Err(Error::io(
            "aio engine died before completing request",
            std::io::Error::new(std::io::ErrorKind::BrokenPipe, "completion channel closed"),
        )),
    )
}

impl AioHandle {
    /// Block until the operation completes. On success or an ordinary
    /// I/O error the submitted buffer comes back (so callers keep their
    /// pool intact); on engine death the buffer is gone and the status
    /// is `Err(Error::Io)` — never a zeroed stand-in.
    pub fn wait(self) -> (Vec<f64>, Result<()>) {
        match self.rx.recv() {
            Ok(pair) => pair,
            Err(_) => lost(),
        }
    }

    /// Non-blocking completion attempt: `Ok` with the result if done,
    /// `Err(self)` (handle returned intact) if still in flight.
    pub fn try_wait(self) -> std::result::Result<(Vec<f64>, Result<()>), AioHandle> {
        match self.rx.try_recv() {
            Ok(pair) => Ok(pair),
            Err(std::sync::mpsc::TryRecvError::Empty) => Err(self),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Ok(lost()),
        }
    }
}

/// One completed slab read, or the news that the engine died with it.
/// Unlike the `Vec` path there is nothing to mint on engine death: the
/// dying thread's unwind drops the [`BlockMut`], whose recycler hands
/// the slab straight back to its pool — a dead engine cannot grow
/// resident memory past the budget.
pub struct SlabHandle {
    rx: Receiver<(BlockMut, Result<()>)>,
}

impl SlabHandle {
    /// Block until the read completes. `None` means the engine died with
    /// the slab (already recycled on the dying side); an `Err` status
    /// with `Some` hands the slab back for reuse.
    pub fn wait(self) -> (Option<BlockMut>, Result<()>) {
        match self.rx.recv() {
            Ok((buf, res)) => (Some(buf), res),
            Err(_) => (None, lost().1),
        }
    }
}

/// Run one positioned read through the fault hook and the policy's
/// bounded retry loop: first failure consults [`fault::policy`], then up
/// to `read_retries` re-attempts with exponential backoff under a total
/// deadline. Positioned reads are idempotent, so re-attempting is always
/// safe. The final failure names the column range and attempt count —
/// the error a permanently bad region surfaces to the caller.
fn read_with_retry(col0: u64, ncols: u64, mut op: impl FnMut() -> Result<()>) -> Result<()> {
    let mut attempt = |c0: u64, nc: u64| -> Result<()> {
        fault::before_read_attempt(c0, nc).map_err(|e| Error::io("injected fault", e))?;
        op()
    };
    let mut res = attempt(col0, ncols);
    if res.is_ok() {
        return res;
    }
    // Only now (a read already failed — off the fast path) is the
    // policy consulted.
    let pol = fault::policy();
    let deadline = Instant::now() + Duration::from_millis(pol.retry_deadline_ms);
    let mut attempts = 1u32;
    while attempts <= pol.read_retries {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        std::thread::sleep(pol.backoff(attempts).min(deadline - now));
        fault::note_read_retry();
        attempts += 1;
        res = attempt(col0, ncols);
        if res.is_ok() {
            return res;
        }
    }
    res.map_err(|e| match e {
        Error::Io { context, source } => Error::Io {
            context: format!(
                "read of cols {col0}..{} failed after {attempts} attempt(s): {context}",
                col0 + ncols
            ),
            source,
        },
        other => other,
    })
}

enum Req {
    Read { block: u64, buf: Vec<f64>, done: Sender<(Vec<f64>, Result<()>)> },
    Write { block: u64, buf: Vec<f64>, done: Sender<(Vec<f64>, Result<()>)> },
    ReadCols { col0: u64, ncols: u64, buf: Vec<f64>, done: Sender<(Vec<f64>, Result<()>)> },
    /// Read straight into an aligned slab — the zero-copy plane's entry
    /// point: the disk bytes land in the buffer the lanes will view.
    ReadColsSlab { col0: u64, ncols: u64, buf: BlockMut, done: Sender<(BlockMut, Result<()>)> },
    WriteCols { col0: u64, ncols: u64, buf: Vec<f64>, done: Sender<(Vec<f64>, Result<()>)> },
    Sync { done: Sender<(Vec<f64>, Result<()>)> },
    /// Data-sync the file, then run `task` with the sync result on this
    /// background thread — the two-phase journal's durable-commit leg:
    /// the commit record and its own fsync ride the aio thread while
    /// the caller streams the next segment.
    SyncThen {
        task: Box<dyn FnOnce(Result<()>) -> Result<()> + Send>,
        done: Sender<(Vec<f64>, Result<()>)>,
    },
    Shutdown,
}

/// Device-side accounting snapshot of one engine: operations completed,
/// on-disk bytes moved (dtype-aware), and the I/O thread's busy time. Because the
/// engine thread measures each operation itself, `busy` is overlap-free —
/// `bytes / busy` is the *effective device bandwidth*, independent of how
/// much of the latency the pipeline managed to hide. The autotuner's
/// adaptive re-planner reads deltas of this to feed the model live rates.
#[derive(Debug, Clone, Copy, Default)]
pub struct AioStats {
    pub ops: u64,
    pub bytes: u64,
    pub busy: Duration,
}

impl AioStats {
    /// Effective bandwidth in MB/s (0 when nothing completed yet).
    pub fn mbps(&self) -> f64 {
        let secs = self.busy.as_secs_f64();
        if secs > 0.0 {
            self.bytes as f64 / secs / 1e6
        } else {
            0.0
        }
    }

    /// Counter difference since an earlier snapshot.
    pub fn since(&self, earlier: &AioStats) -> AioStats {
        AioStats {
            ops: self.ops.saturating_sub(earlier.ops),
            bytes: self.bytes.saturating_sub(earlier.bytes),
            busy: self.busy.saturating_sub(earlier.busy),
        }
    }
}

#[derive(Default)]
struct StatsCells {
    ops: AtomicU64,
    bytes: AtomicU64,
    busy_nanos: AtomicU64,
}

impl StatsCells {
    fn record(&self, bytes: u64, elapsed: Duration) {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.busy_nanos.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Async engine over one [`XrdFile`].
pub struct AioEngine {
    tx: Option<Sender<Req>>,
    worker: Option<JoinHandle<()>>,
    stats: Arc<StatsCells>,
}

impl AioEngine {
    /// Spawn the I/O thread owning `file`.
    pub fn new(file: XrdFile) -> Self {
        let (tx, rx) = channel::<Req>();
        let stats = Arc::new(StatsCells::default());
        let cells = Arc::clone(&stats);
        // Stats count *on-disk* bytes (dtype-aware): `bytes / busy` must
        // be the device's real bandwidth, also for half-width f32 files.
        let elem_bytes = file.header().dtype.bytes();
        let worker = std::thread::Builder::new()
            .name("cugwas-aio".into())
            .spawn(move || {
                // Every op is timed anyway (the stats need it); the same
                // measurement doubles as a trace span on the aio track.
                let traced = |name: &'static str, key: &'static str, id: u64, t0: Instant| {
                    let took = t0.elapsed();
                    crate::telemetry::span(
                        name,
                        "io",
                        crate::telemetry::trace::TID_AIO,
                        t0,
                        took,
                        &[(key, id)],
                    );
                    took
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Read { block, mut buf, done } => {
                            let t0 = Instant::now();
                            let h = *file.header();
                            let res = read_with_retry(block * h.block_cols, h.block_cols, || {
                                file.read_block_into(block, &mut buf)
                            });
                            let took = traced("read", "block", block, t0);
                            cells.record(buf.len() as u64 * elem_bytes, took);
                            let _ = done.send((buf, res));
                        }
                        Req::Write { block, buf, done } => {
                            let t0 = Instant::now();
                            let res = file.write_block(block, &buf);
                            let took = traced("write", "block", block, t0);
                            cells.record(buf.len() as u64 * elem_bytes, took);
                            let _ = done.send((buf, res));
                        }
                        Req::ReadCols { col0, ncols, mut buf, done } => {
                            let t0 = Instant::now();
                            let res = read_with_retry(col0, ncols, || {
                                file.read_cols_into(col0, ncols, &mut buf)
                            });
                            let took = traced("read", "col0", col0, t0);
                            cells.record(buf.len() as u64 * elem_bytes, took);
                            let _ = done.send((buf, res));
                        }
                        Req::ReadColsSlab { col0, ncols, mut buf, done } => {
                            let t0 = Instant::now();
                            let res = read_with_retry(col0, ncols, || {
                                file.read_cols_into(col0, ncols, buf.as_mut_slice())
                            });
                            if res.is_ok() {
                                // Checksum what the disk delivered; the
                                // corruption hook fires *after* so rot
                                // between here and the consumer is what
                                // the verify points catch.
                                if fault::integrity_enabled() {
                                    buf.set_checksum(fault::checksum(buf.as_mut_slice()));
                                }
                                fault::corrupt_payload(buf.as_mut_slice());
                            }
                            let took = traced("read", "col0", col0, t0);
                            cells.record(buf.len() as u64 * elem_bytes, took);
                            let _ = done.send((buf, res));
                        }
                        Req::WriteCols { col0, ncols, buf, done } => {
                            let t0 = Instant::now();
                            let res = file.write_cols(col0, ncols, &buf);
                            let took = traced("write", "col0", col0, t0);
                            cells.record(buf.len() as u64 * elem_bytes, took);
                            let _ = done.send((buf, res));
                        }
                        Req::Sync { done } => {
                            let _ = done.send((Vec::new(), file.sync()));
                        }
                        Req::SyncThen { task, done } => {
                            let t0 = Instant::now();
                            let res = task(file.sync());
                            traced("sync_then", "ops", 0, t0);
                            let _ = done.send((Vec::new(), res));
                        }
                        Req::Shutdown => break,
                    }
                }
            })
            .expect("spawning aio thread");
        AioEngine { tx: Some(tx), worker: Some(worker), stats }
    }

    /// Snapshot the engine's device-side counters.
    pub fn stats(&self) -> AioStats {
        AioStats {
            ops: self.stats.ops.load(Ordering::Relaxed),
            bytes: self.stats.bytes.load(Ordering::Relaxed),
            busy: Duration::from_nanos(self.stats.busy_nanos.load(Ordering::Relaxed)),
        }
    }

    fn submit(&self, req: Req) {
        self.tx
            .as_ref()
            .expect("engine already shut down")
            .send(req)
            .expect("aio thread alive");
    }

    /// `aio_read`: fill `buf` from block `b` asynchronously.
    pub fn read(&self, block: u64, buf: Vec<f64>) -> AioHandle {
        let (done, rx) = channel();
        self.submit(Req::Read { block, buf, done });
        AioHandle { rx }
    }

    /// `aio_write`: write `buf` to block `b` asynchronously.
    pub fn write(&self, block: u64, buf: Vec<f64>) -> AioHandle {
        let (done, rx) = channel();
        self.submit(Req::Write { block, buf, done });
        AioHandle { rx }
    }

    /// `aio_read` of a column range straight into an aligned slab. The
    /// caller publishes the returned [`BlockMut`] once the read
    /// completes; the cache and the device lanes then share the very
    /// bytes the disk delivered — no host copy anywhere on the plane.
    pub fn read_cols_slab(&self, col0: u64, ncols: u64, buf: BlockMut) -> SlabHandle {
        let (done, rx) = channel();
        self.submit(Req::ReadColsSlab { col0, ncols, buf, done });
        SlabHandle { rx }
    }

    /// `aio_read` of an arbitrary column range (block-size-agnostic).
    pub fn read_cols(&self, col0: u64, ncols: u64, buf: Vec<f64>) -> AioHandle {
        let (done, rx) = channel();
        self.submit(Req::ReadCols { col0, ncols, buf, done });
        AioHandle { rx }
    }

    /// `aio_write` of an arbitrary column range.
    pub fn write_cols(&self, col0: u64, ncols: u64, buf: Vec<f64>) -> AioHandle {
        let (done, rx) = channel();
        self.submit(Req::WriteCols { col0, ncols, buf, done });
        AioHandle { rx }
    }

    /// Queue a data sync behind all submitted operations.
    pub fn sync(&self) -> AioHandle {
        let (done, rx) = channel();
        self.submit(Req::Sync { done });
        AioHandle { rx }
    }

    /// Queue a data sync behind all submitted operations, then run
    /// `task(sync_result)` on the I/O thread. The FIFO request queue
    /// guarantees every previously submitted write lands before the
    /// sync; the handle resolves to `task`'s result. This is how the
    /// coordinator overlaps the journal's durable commit with the next
    /// segment's reads: the boundary only *schedules* the sync+commit
    /// and reaps it one segment later.
    pub fn sync_then(
        &self,
        task: impl FnOnce(Result<()>) -> Result<()> + Send + 'static,
    ) -> AioHandle {
        let (done, rx) = channel();
        self.submit(Req::SyncThen { task: Box::new(task), done });
        AioHandle { rx }
    }
}

impl Drop for AioEngine {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Req::Shutdown);
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Result of a sequential read-bandwidth probe.
#[derive(Debug, Clone, Copy)]
pub struct ReadProbe {
    /// On-disk bytes streamed (dtype-aware, excludes the header).
    pub bytes: u64,
    /// Wall seconds from first submission to last completion.
    pub secs: f64,
    /// Read requests issued. Probing at two different window sizes and
    /// comparing per-request times separates the device's per-request
    /// latency from its linear bandwidth (see `tune::probe`).
    pub ops: u64,
}

impl ReadProbe {
    pub fn mbps(&self) -> f64 {
        if self.secs > 0.0 {
            self.bytes as f64 / self.secs / 1e6
        } else {
            0.0
        }
    }
}

/// Measure effective sequential read bandwidth of `file` by streaming up
/// to `max_bytes` of it through an [`AioEngine`] with `depth` requests in
/// flight — the exact I/O pattern the pipeline's read-ahead produces, so
/// the probed rate is what the coordinator will actually see. The file's
/// throttle (if attached) is honored, which lets `cugwas tune` calibrate
/// against an emulated slower device.
pub fn probe_read_bandwidth(file: XrdFile, max_bytes: u64, depth: usize) -> Result<ReadProbe> {
    // ~4 MB windows: big enough to amortize per-request overhead, small
    // enough that several fit in flight at `depth` ≥ 2.
    probe_read_bandwidth_windowed(file, max_bytes, depth, 4 << 20)
}

/// [`probe_read_bandwidth`] with an explicit request-window size. The
/// autotuner probes twice (small + large windows) to fit the device's
/// per-request latency alongside its linear bandwidth.
pub fn probe_read_bandwidth_windowed(
    file: XrdFile,
    max_bytes: u64,
    depth: usize,
    window_bytes: u64,
) -> Result<ReadProbe> {
    let h = *file.header();
    if h.rows == 0 || h.cols == 0 {
        return Err(Error::Config("probe: file has no data".into()));
    }
    let col_disk_bytes = h.rows * h.dtype.bytes();
    let window_bytes = window_bytes.max(1).min(max_bytes.max(col_disk_bytes));
    let wcols = (window_bytes / col_disk_bytes).clamp(1, h.cols);
    let engine = AioEngine::new(file);
    let depth = depth.max(1);
    let mut inflight: std::collections::VecDeque<AioHandle> =
        std::collections::VecDeque::with_capacity(depth);
    let mut col0 = 0u64;
    let mut bytes = 0u64;
    let mut ops = 0u64;
    let t0 = Instant::now();
    loop {
        while col0 < h.cols && bytes < max_bytes && inflight.len() < depth {
            let ncols = wcols.min(h.cols - col0);
            let buf = vec![0.0f64; (h.rows * ncols) as usize];
            inflight.push_back(engine.read_cols(col0, ncols, buf));
            col0 += ncols;
            bytes += ncols * col_disk_bytes;
            ops += 1;
        }
        let Some(handle) = inflight.pop_front() else { break };
        handle.wait().1?;
    }
    Ok(ReadProbe { bytes, secs: t0.elapsed().as_secs_f64(), ops })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::format::Header;
    use std::path::PathBuf;

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cugwas_aio_{}_{tag}.xrd", std::process::id()))
    }

    #[test]
    fn async_roundtrip_preserves_data_and_buffers() {
        let p = tmpfile("rt");
        let h = Header::new(8, 9, 3, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        // Write all blocks asynchronously.
        let mut handles = Vec::new();
        for b in 0..3u64 {
            let data: Vec<f64> = (0..24).map(|i| b as f64 * 100.0 + i as f64).collect();
            handles.push(eng.write(b, data));
        }
        for hd in handles {
            let (buf, res) = hd.wait();
            res.unwrap();
            assert_eq!(buf.len(), 24); // buffer comes back for reuse
        }
        // Read them back out of order.
        for &b in &[2u64, 0, 1] {
            let (buf, res) = eng.read(b, vec![0.0; 24]).wait();
            res.unwrap();
            assert_eq!(buf[0], b as f64 * 100.0);
        }
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn overlapping_submissions_complete_in_order() {
        let p = tmpfile("order");
        let h = Header::new(16, 20, 5, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        let w: Vec<AioHandle> =
            (0..4).map(|b| eng.write(b, vec![b as f64; 80])).collect();
        // Submit dependent reads before waiting on the writes: FIFO per
        // engine guarantees the reads see the written data.
        let r: Vec<AioHandle> = (0..4).map(|b| eng.read(b, vec![0.0; 80])).collect();
        for hd in w {
            hd.wait().1.unwrap();
        }
        for (b, hd) in r.into_iter().enumerate() {
            let (buf, res) = hd.wait();
            res.unwrap();
            assert!(buf.iter().all(|&v| v == b as f64));
        }
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn error_surfaces_but_buffer_survives() {
        let p = tmpfile("err");
        let h = Header::new(4, 4, 2, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        let (buf, res) = eng.read(7, vec![0.0; 8]).wait(); // out of range
        assert!(res.is_err());
        assert_eq!(buf.len(), 8);
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn dead_engine_surfaces_io_error_not_zeroed_buffer() {
        // Simulate engine death with a request in flight: the completion
        // sender is gone without ever delivering. The caller must get a
        // hard Error::Io and an EMPTY buffer — a correctly-sized zeroed
        // replacement would be silently computable-on, which is exactly
        // the corruption this path used to cause.
        let (tx, rx) = channel::<(Vec<f64>, Result<()>)>();
        drop(tx);
        let h = AioHandle { rx };
        let (buf, res) = h.wait();
        assert!(buf.is_empty(), "no plausible stand-in buffer on engine death");
        match res {
            Err(Error::Io { context, source }) => {
                assert!(context.contains("engine died"), "{context}");
                assert_eq!(source.kind(), std::io::ErrorKind::BrokenPipe);
            }
            other => panic!("expected Error::Io, got {other:?}"),
        }

        let (tx, rx) = channel::<(Vec<f64>, Result<()>)>();
        drop(tx);
        let h = AioHandle { rx };
        let (buf, res) = h.try_wait().expect("disconnected resolves immediately");
        assert!(buf.is_empty());
        assert!(matches!(res, Err(Error::Io { .. })), "{res:?}");
    }

    #[test]
    fn read_retry_recovers_transients_and_names_the_range_on_permanents() {
        // Transient: fails twice, succeeds on the third attempt (within
        // the default policy's retry budget).
        let mut calls = 0;
        read_with_retry(0, 4, || {
            calls += 1;
            if calls < 3 {
                Err(Error::io("flaky", std::io::Error::other("transient")))
            } else {
                Ok(())
            }
        })
        .unwrap();
        assert_eq!(calls, 3);
        // Permanent: retries exhaust and the final error names the
        // column range and attempt count.
        let err = read_with_retry(10, 4, || {
            Err(Error::io("bad sector", std::io::Error::other("medium error")))
        })
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("cols 10..14"), "{msg}");
        assert!(msg.contains("attempt"), "{msg}");
        assert!(msg.contains("bad sector"), "{msg}");
    }

    #[test]
    fn slab_read_lands_disk_bytes_in_the_slab() {
        use crate::storage::slab::SlabPool;
        let p = tmpfile("slab");
        let h = Header::new(8, 9, 3, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        let data: Vec<f64> = (0..24).map(|i| i as f64).collect();
        eng.write(0, data.clone()).wait().1.unwrap();
        let pool = SlabPool::new(2, 24);
        let (bm, res) = eng.read_cols_slab(0, 3, pool.take(24).unwrap()).wait();
        res.unwrap();
        let block = bm.expect("engine alive").publish();
        assert_eq!(block.as_slice(), &data[..]);
        // Stats count the slab read like any other operation.
        assert_eq!(eng.stats().ops, 2);
        // An out-of-range slab read surfaces the error and the slab.
        let (bm, res) = eng.read_cols_slab(7, 3, pool.take(24).unwrap()).wait();
        assert!(res.is_err());
        drop(bm.expect("slab survives an I/O error"));
        drop(block);
        assert_eq!(pool.stats().free, 2, "both slabs back in the pool");
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn dead_engine_returns_the_slab_to_its_pool() {
        use crate::storage::slab::SlabPool;
        // Simulate engine death with a slab read in flight: the request
        // (and the BlockMut inside it) is dropped on the dying side, so
        // the slab must land back in the pool — no replacement minted,
        // no resident-memory growth past the budget.
        let pool = SlabPool::new(1, 16);
        let bm = pool.take(16).unwrap();
        assert_eq!(pool.stats().free, 0);
        let (tx, rx) = channel::<(BlockMut, Result<()>)>();
        let holder = std::thread::spawn(move || drop(bm)); // the "dying engine"
        holder.join().unwrap();
        drop(tx);
        let h = SlabHandle { rx };
        let (buf, res) = h.wait();
        assert!(buf.is_none(), "nothing minted for a lost slab");
        assert!(res.is_err());
        let s = pool.stats();
        assert_eq!(s.free, 1, "slab recycled by the dying side's drop");
        assert_eq!(s.minted, 0);
    }

    #[test]
    fn stats_track_ops_bytes_and_busy_time() {
        let p = tmpfile("stats");
        let h = Header::new(8, 6, 3, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        assert_eq!(eng.stats().ops, 0);
        eng.write(0, vec![1.0; 24]).wait().1.unwrap();
        eng.read(0, vec![0.0; 24]).wait().1.unwrap();
        let s = eng.stats();
        assert_eq!(s.ops, 2);
        assert_eq!(s.bytes, 2 * 24 * 8);
        let base = s;
        eng.read(1, vec![0.0; 24]).wait().1.unwrap();
        let d = eng.stats().since(&base);
        assert_eq!(d.ops, 1);
        assert_eq!(d.bytes, 24 * 8);
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn probe_read_bandwidth_streams_the_file() {
        let p = tmpfile("probe");
        let h = Header::new(32, 64, 8, 0).unwrap();
        let f = XrdFile::create(&p, h).unwrap();
        for b in 0..h.block_count() {
            let n = (h.cols_in_block(b) * h.rows) as usize;
            f.write_block(b, &vec![1.0; n]).unwrap();
        }
        drop(f);
        let probe = probe_read_bandwidth(XrdFile::open(&p).unwrap(), u64::MAX, 2).unwrap();
        assert_eq!(probe.bytes, 32 * 64 * 8);
        assert!(probe.mbps() > 0.0);
        assert!(probe.ops >= 1);
        // A byte cap stops the probe early (whole windows only).
        let capped = probe_read_bandwidth(XrdFile::open(&p).unwrap(), 1, 2).unwrap();
        assert!(capped.bytes >= 32 * 8 && capped.bytes < 32 * 64 * 8);
        // A small explicit window splits the same file into more requests.
        let windowed =
            probe_read_bandwidth_windowed(XrdFile::open(&p).unwrap(), u64::MAX, 2, 32 * 8)
                .unwrap();
        assert_eq!(windowed.bytes, 32 * 64 * 8);
        assert!(windowed.ops > probe.ops, "{} vs {}", windowed.ops, probe.ops);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn sync_completes() {
        let p = tmpfile("sync");
        let h = Header::new(4, 4, 2, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        eng.write(0, vec![1.0; 8]).wait().1.unwrap();
        eng.sync().wait().1.unwrap();
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn sync_then_runs_the_task_behind_queued_writes() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let p = tmpfile("syncthen");
        let h = Header::new(4, 4, 2, 0).unwrap();
        let eng = AioEngine::new(XrdFile::create(&p, h).unwrap());
        // Submit a write and, without waiting, the sync+task: FIFO
        // ordering must run the task only after the write landed.
        let wh = eng.write(0, vec![2.5; 8]);
        let ran = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&ran);
        let th = eng.sync_then(move |sync_res| {
            sync_res?;
            flag.store(true, Ordering::SeqCst);
            Ok(())
        });
        th.wait().1.unwrap();
        assert!(ran.load(Ordering::SeqCst));
        wh.wait().1.unwrap();
        // The task's own failure surfaces through the handle.
        let (_, res) = eng
            .sync_then(|sync_res| {
                sync_res?;
                Err(Error::io("commit failed", std::io::Error::other("boom")))
            })
            .wait();
        assert!(res.unwrap_err().to_string().contains("commit failed"));
        drop(eng);
        std::fs::remove_file(&p).unwrap();
    }
}
