//! Fault injection and fault-tolerance policy — the chaos harness and
//! the knobs that govern how the pipeline survives it.
//!
//! The paper's claim is *sustained* peak over multi-hour streams; a
//! pipeline that dies (or worse, silently zeroes a block) on the first
//! transient read error cannot sustain anything. This module supplies
//! both halves of the fix:
//!
//! * **Policy** ([`RetryPolicy`], `[fault_tolerance]` in config): how
//!   many times the aio engine retries a failed read, with what backoff
//!   and deadline; whether published blocks carry an integrity checksum
//!   that is re-verified on cache hits and before lane submission; how
//!   long a device lane may sit without progress before the watchdog
//!   declares it wedged; how often a lane is respawned and a failed job
//!   re-queued before giving up.
//! * **Injection** ([`FaultPlan`]): a deterministic, seeded injector
//!   that can fail reads transiently or permanently, delay them,
//!   corrupt delivered bytes *after* the checksum was taken (rot
//!   between disk and consumer), tear a journal append mid-record, and
//!   wedge a device lane. Every decision is a pure function of the
//!   plan and a per-site operation counter, so a run with a pinned
//!   `CUGWAS_FAULT_SEED` replays the exact same fault schedule.
//!
//! **Disabled faults are free.** Exactly like the telemetry plane, both
//! the injector and the integrity checker sit behind a global
//! `AtomicBool`; every hook begins with one relaxed load and returns
//! before touching a lock, hashing a byte or reading the plan. `run`
//! and `serve` without a `[fault_tolerance]` section never materialize
//! the state.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Sentinel for "no column targeted" in [`FaultPlan::read_fail_col`].
pub const NO_COL: u64 = u64::MAX;
/// Sentinel for "no lane targeted" in [`FaultPlan::wedge_lane`].
pub const NO_LANE: usize = usize::MAX;
/// Sentinel for "no free-space override" in
/// [`FaultPlan::fake_disk_free_mb`].
pub const NO_DISK: u64 = u64::MAX;

static FAULTS_ON: AtomicBool = AtomicBool::new(false);
static INTEGRITY_ON: AtomicBool = AtomicBool::new(false);

/// Whether the injector is live (one relaxed load — the entire cost of
/// disabled fault injection on the hot path).
#[inline(always)]
pub fn faults_enabled() -> bool {
    FAULTS_ON.load(Ordering::Relaxed)
}

/// Whether block checksums are computed and verified (one relaxed load
/// per read/submit point when off).
#[inline(always)]
pub fn integrity_enabled() -> bool {
    INTEGRITY_ON.load(Ordering::Relaxed)
}

/// Turn integrity checking on/off (done once at startup from
/// `[fault_tolerance] integrity`; tests flip it in their own process).
pub fn set_integrity_enabled(on: bool) {
    INTEGRITY_ON.store(on, Ordering::Release);
}

// ---------------------------------------------------------------------
// Retry / supervision policy
// ---------------------------------------------------------------------

/// How the pipeline responds to faults — the `[fault_tolerance]`
/// section minus the injection knobs. Process-global, installed once at
/// startup; defaults keep every behavior of a policy-free build except
/// that transient read errors are retried a few times before failing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Extra read attempts after the first failure (0 = fail fast).
    pub read_retries: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub retry_backoff_ms: u64,
    /// Total time budget across all retries of one read.
    pub retry_deadline_ms: u64,
    /// No lane progress for this long while chunks are outstanding is a
    /// wedge (0 = watchdog off).
    pub lane_watchdog_ms: u64,
    /// Lane respawn + segment replay attempts before a lane fault is a
    /// job failure.
    pub max_lane_respawns: u32,
    /// Times a failed job re-enters the service queue before its
    /// failure is final.
    pub job_retries: u32,
    /// Delay before a failed job may be admitted again; doubles per
    /// attempt.
    pub job_backoff_ms: u64,
    /// Consecutive job failures on one dataset before the dataset is
    /// quarantined (further jobs fail immediately instead of running).
    pub quarantine_after: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            read_retries: 3,
            retry_backoff_ms: 10,
            retry_deadline_ms: 2_000,
            lane_watchdog_ms: 0,
            max_lane_respawns: 2,
            job_retries: 1,
            job_backoff_ms: 100,
            quarantine_after: 3,
        }
    }
}

impl RetryPolicy {
    /// Backoff for retry number `attempt` (1-based), exponentially
    /// doubled and capped so a misconfigured policy cannot sleep for
    /// minutes inside the aio thread.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let ms = self.retry_backoff_ms.saturating_mul(1u64 << attempt.min(10).saturating_sub(1));
        Duration::from_millis(ms.min(self.retry_deadline_ms))
    }
}

static POLICY: Mutex<Option<RetryPolicy>> = Mutex::new(None);

/// Install the process-wide policy (startup / test setup).
pub fn set_policy(p: RetryPolicy) {
    *POLICY.lock().unwrap() = Some(p);
}

/// The active policy. Only consulted on error/supervision paths (after
/// a read already failed, when a watchdog timer fires), never on the
/// per-block fast path — so a mutex is fine here.
pub fn policy() -> RetryPolicy {
    POLICY.lock().unwrap().unwrap_or_default()
}

// ---------------------------------------------------------------------
// Fault counters
// ---------------------------------------------------------------------

/// Monotone process-wide fault/recovery counters. Incremented on the
/// (already slow) fault paths regardless of telemetry state so tests
/// and reports can assert on them; mirrored into the Prometheus
/// registry when the metrics plane is on.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Faults the injector actually delivered.
    pub injected: u64,
    /// Read attempts beyond the first (aio retry loop + integrity
    /// re-reads).
    pub read_retries: u64,
    /// Device-lane respawn + segment replay recoveries.
    pub lane_respawns: u64,
    /// Failed jobs re-entering the service queue.
    pub job_retries: u64,
}

static INJECTED: AtomicU64 = AtomicU64::new(0);
static READ_RETRIES: AtomicU64 = AtomicU64::new(0);
static LANE_RESPAWNS: AtomicU64 = AtomicU64::new(0);
static JOB_RETRIES: AtomicU64 = AtomicU64::new(0);

/// Snapshot the process-wide counters.
pub fn counters() -> FaultCounters {
    FaultCounters {
        injected: INJECTED.load(Ordering::Relaxed),
        read_retries: READ_RETRIES.load(Ordering::Relaxed),
        lane_respawns: LANE_RESPAWNS.load(Ordering::Relaxed),
        job_retries: JOB_RETRIES.load(Ordering::Relaxed),
    }
}

fn mirror(f: impl FnOnce(&crate::telemetry::Registry)) {
    if crate::telemetry::metrics_enabled() {
        f(crate::telemetry::global());
    }
}

fn note_injected() {
    INJECTED.fetch_add(1, Ordering::Relaxed);
    mirror(|r| r.faults_injected_total.add(1));
}

/// Record one read retry (called by the aio retry loop and by the
/// integrity re-read path).
pub fn note_read_retry() {
    READ_RETRIES.fetch_add(1, Ordering::Relaxed);
    mirror(|r| r.read_retries_total.add(1));
}

/// Record one lane respawn recovery (called by the engine supervisor).
pub fn note_lane_respawn() {
    LANE_RESPAWNS.fetch_add(1, Ordering::Relaxed);
    mirror(|r| r.lane_respawns_total.add(1));
}

/// Record one job re-queue (called by the service scheduler).
pub fn note_job_retry() {
    JOB_RETRIES.fetch_add(1, Ordering::Relaxed);
    mirror(|r| r.job_retries_total.add(1));
}

// ---------------------------------------------------------------------
// Checksums
// ---------------------------------------------------------------------

/// FNV-1a over the raw bytes of a block payload — cheap enough to run
/// at disk speed, strong enough that a flipped byte cannot hide. The
/// sentinel 0 means "no checksum recorded", so a computed hash of 0 is
/// nudged to 1.
pub fn checksum(data: &[f64]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    // Hash 8 bytes per multiply (the f64 bit pattern) instead of
    // byte-at-a-time: ~8x fewer multiplies, same avalanche for our
    // purpose (detecting corruption, not adversaries).
    for v in data {
        h ^= v.to_bits();
        h = h.wrapping_mul(PRIME);
    }
    if h == 0 {
        1
    } else {
        h
    }
}

// ---------------------------------------------------------------------
// Injection plan
// ---------------------------------------------------------------------

/// A deterministic fault schedule. Every field is "off" by default;
/// periods are in *events at that site* (read attempts, published
/// blocks, journal appends, lane chunks), so a plan plus a seed fully
/// determines which events fault — independent of timing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed stirring the deterministic corruption positions.
    pub seed: u64,
    /// Every Nth read *attempt* fails with a transient I/O error
    /// (0 = off). Retries are attempts too, so `1` means permanent.
    pub read_fail_every: u64,
    /// Reads covering this column always fail — a permanently bad
    /// region ([`NO_COL`] = off).
    pub read_fail_col: u64,
    /// Every Nth read attempt sleeps [`FaultPlan::read_delay_ms`]
    /// before touching the disk (0 = off).
    pub read_delay_every: u64,
    pub read_delay_ms: u64,
    /// Every Nth successfully delivered slab read has one byte flipped
    /// *after* its checksum was computed (0 = off) — the
    /// disk-to-consumer rot that integrity checking exists to catch.
    pub corrupt_every: u64,
    /// The Nth journal append (1-based) writes half a record and
    /// reports failure, simulating a crash mid-append (0 = off).
    pub torn_append_at: u64,
    /// The Nth journal *commit* (1-based) fails before its durable mark
    /// lands, simulating a crash between the intent records and the
    /// commit sync — resume must replay the unsealed segment (0 = off).
    pub commit_crash_at: u64,
    /// Lane to wedge ([`NO_LANE`] = off)…
    pub wedge_lane: usize,
    /// …on receiving its Nth chunk (1-based)…
    pub wedge_at_chunk: u64,
    /// …by sleeping this long before dropping the chunk on the floor.
    pub wedge_ms: u64,
    /// The Nth service-WAL append (1-based) writes half its record and
    /// reports a crash — a power cut mid-append; replay must drop the
    /// torn tail (0 = off).
    pub wal_torn_append_at: u64,
    /// The Nth service-WAL append (1-based) crashes *before* the record
    /// lands — the crash window between the progress journal's state
    /// and the WAL's record of it; restart must reconcile from the
    /// journal, not the WAL (0 = off).
    pub wal_crash_at: u64,
    /// The Nth quarantine/spool rename (1-based) crashes after the
    /// rename but before the directory sync that makes it durable —
    /// recovery must tolerate the entry landing in either directory
    /// (0 = off).
    pub quarantine_crash_at: u64,
    /// Report this many MB free to the disk-space sentinel instead of
    /// asking the filesystem — the deterministic way to rehearse
    /// ENOSPC degradation ([`NO_DISK`] = off).
    pub fake_disk_free_mb: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            read_fail_every: 0,
            read_fail_col: NO_COL,
            read_delay_every: 0,
            read_delay_ms: 0,
            corrupt_every: 0,
            torn_append_at: 0,
            commit_crash_at: 0,
            wedge_lane: NO_LANE,
            wedge_at_chunk: 1,
            wedge_ms: 3_000,
            wal_torn_append_at: 0,
            wal_crash_at: 0,
            quarantine_crash_at: 0,
            fake_disk_free_mb: NO_DISK,
        }
    }
}

impl FaultPlan {
    fn active(&self) -> bool {
        self.read_fail_every > 0
            || self.read_fail_col != NO_COL
            || self.read_delay_every > 0
            || self.corrupt_every > 0
            || self.torn_append_at > 0
            || self.commit_crash_at > 0
            || self.wedge_lane != NO_LANE
            || self.wal_torn_append_at > 0
            || self.wal_crash_at > 0
            || self.quarantine_crash_at > 0
            || self.fake_disk_free_mb != NO_DISK
    }
}

/// Plan plus per-site event counters — all consumed under one mutex,
/// only ever touched when [`faults_enabled`] already returned true.
struct FaultState {
    plan: FaultPlan,
    read_attempts: u64,
    published: u64,
    appends: u64,
    commits: u64,
    chunks: u64,
    wedged: bool,
    wal_appends: u64,
    quarantine_renames: u64,
}

static STATE: Mutex<Option<FaultState>> = Mutex::new(None);

/// Arm the injector with `plan` (resetting all event counters), or
/// disarm it when the plan is all-off. `CUGWAS_FAULT_SEED` in the
/// environment overrides `plan.seed` so CI can pin a schedule without
/// editing configs.
pub fn arm(plan: FaultPlan) {
    let mut plan = plan;
    if let Ok(s) = std::env::var("CUGWAS_FAULT_SEED") {
        if let Ok(seed) = s.trim().parse::<u64>() {
            plan.seed = seed;
        }
    }
    let on = plan.active();
    *STATE.lock().unwrap() = on.then(|| FaultState {
        plan,
        read_attempts: 0,
        published: 0,
        appends: 0,
        commits: 0,
        chunks: 0,
        wedged: false,
        wal_appends: 0,
        quarantine_renames: 0,
    });
    FAULTS_ON.store(on, Ordering::Release);
}

/// Disarm the injector (used between chaos-test scenarios).
pub fn disarm() {
    arm(FaultPlan::default());
}

fn with_state<T>(f: impl FnOnce(&mut FaultState) -> T) -> Option<T> {
    let mut g = STATE.lock().unwrap();
    g.as_mut().map(f)
}

/// splitmix64 — the deterministic stir for corruption positions.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------
// Hooks (each begins with the one relaxed load)
// ---------------------------------------------------------------------

/// Called before every read attempt in the aio worker. May sleep (delay
/// injection) and may return an injected `io::Error` (transient by
/// schedule, permanent by column).
pub fn before_read_attempt(col0: u64, ncols: u64) -> std::io::Result<()> {
    if !faults_enabled() {
        return Ok(());
    }
    let verdict = with_state(|st| {
        st.read_attempts += 1;
        let n = st.read_attempts;
        let p = &st.plan;
        let delay = (p.read_delay_every > 0 && n % p.read_delay_every == 0)
            .then(|| Duration::from_millis(p.read_delay_ms));
        let permanent = (p.read_fail_col != NO_COL
            && col0 <= p.read_fail_col
            && p.read_fail_col < col0 + ncols)
            .then_some(p.read_fail_col);
        let transient = p.read_fail_every > 0 && n % p.read_fail_every == 0;
        (delay, permanent, transient)
    });
    let Some((delay, permanent, transient)) = verdict else { return Ok(()) };
    if let Some(d) = delay {
        note_injected();
        std::thread::sleep(d);
    }
    if let Some(col) = permanent {
        note_injected();
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("injected permanent read fault at column {col}"),
        ));
    }
    if transient {
        note_injected();
        return Err(std::io::Error::new(
            std::io::ErrorKind::Interrupted,
            "injected transient read fault",
        ));
    }
    Ok(())
}

/// Called after a successful slab read, *after* its checksum was
/// computed: every Nth delivered payload gets one byte flipped at a
/// seed-determined position. Returns true when it corrupted.
pub fn corrupt_payload(data: &mut [f64]) -> bool {
    if !faults_enabled() || data.is_empty() {
        return false;
    }
    let hit = with_state(|st| {
        st.published += 1;
        (st.plan.corrupt_every > 0 && st.published % st.plan.corrupt_every == 0)
            .then(|| mix(st.plan.seed ^ st.published))
    })
    .flatten();
    let Some(r) = hit else { return false };
    let i = (r as usize) % data.len();
    data[i] = f64::from_bits(data[i].to_bits() ^ (1u64 << (mix(r) % 52)));
    note_injected();
    true
}

/// Called by `Journal::append_intent`: `Some(k)` tears the current append
/// after `k` of its `len` record bytes (simulated crash — the caller
/// writes the prefix, syncs, and reports failure).
pub fn torn_append(len: usize) -> Option<usize> {
    if !faults_enabled() {
        return None;
    }
    let torn = with_state(|st| {
        st.appends += 1;
        st.plan.torn_append_at > 0 && st.appends == st.plan.torn_append_at
    })
    .unwrap_or(false);
    if torn {
        note_injected();
        Some(len / 2)
    } else {
        None
    }
}

/// Called by `Journal::commit` before the durable mark is appended:
/// `true` means this commit crashes (simulated) with neither the mark
/// nor the sync on disk — the preceding intents stay unsealed.
pub fn commit_crash() -> bool {
    if !faults_enabled() {
        return false;
    }
    let hit = with_state(|st| {
        st.commits += 1;
        st.plan.commit_crash_at > 0 && st.commits == st.plan.commit_crash_at
    })
    .unwrap_or(false);
    if hit {
        note_injected();
    }
    hit
}

/// Verdict of [`wal_append_fault`] for one service-WAL append.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalFault {
    /// Write only this many of the record's bytes, then report a crash.
    Torn(usize),
    /// Crash before any of the record lands.
    Crash,
}

/// Called by `Wal::append` once per record, before writing `len` bytes.
/// Both WAL injectors share one append counter so a plan arming both
/// schedules them against the same event stream.
pub fn wal_append_fault(len: usize) -> Option<WalFault> {
    if !faults_enabled() {
        return None;
    }
    let hit = with_state(|st| {
        st.wal_appends += 1;
        let p = &st.plan;
        if p.wal_crash_at > 0 && st.wal_appends == p.wal_crash_at {
            Some(WalFault::Crash)
        } else if p.wal_torn_append_at > 0 && st.wal_appends == p.wal_torn_append_at {
            Some(WalFault::Torn(len / 2))
        } else {
            None
        }
    })
    .flatten()?;
    note_injected();
    Some(hit)
}

/// Called by the scheduler's quarantine/spool mover after the rename
/// but before the directory sync: `true` simulates a crash in the
/// window where the rename is visible but not yet durable.
pub fn quarantine_crash() -> bool {
    if !faults_enabled() {
        return false;
    }
    let hit = with_state(|st| {
        st.quarantine_renames += 1;
        st.plan.quarantine_crash_at > 0 && st.quarantine_renames == st.plan.quarantine_crash_at
    })
    .unwrap_or(false);
    if hit {
        note_injected();
    }
    hit
}

/// Free-bytes override for the disk-space sentinel: `Some(bytes)` makes
/// every probe report exactly that much free, letting tests rehearse
/// low-water degradation without filling a real filesystem.
pub fn fake_disk_free() -> Option<u64> {
    if !faults_enabled() {
        return None;
    }
    with_state(|st| (st.plan.fake_disk_free_mb != NO_DISK).then(|| st.plan.fake_disk_free_mb << 20))
        .flatten()
}

/// Called by a device lane per received chunk: `Some(d)` tells lane
/// `lane` to sleep `d` and drop the chunk (a one-shot wedge — the
/// watchdog, not the lane, is supposed to notice).
pub fn lane_wedge(lane: usize) -> Option<Duration> {
    if !faults_enabled() {
        return None;
    }
    let ms = with_state(|st| {
        if st.plan.wedge_lane != lane || st.wedged {
            return None;
        }
        st.chunks += 1;
        if st.chunks >= st.plan.wedge_at_chunk.max(1) {
            st.wedged = true;
            Some(st.plan.wedge_ms)
        } else {
            None
        }
    })
    .flatten()?;
    note_injected();
    Some(Duration::from_millis(ms))
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the enable flag and counters are process-global and lib
    // unit tests share one process, so these tests never arm the
    // injector — the armed paths live in `tests/fault_injection.rs`,
    // its own binary. Here we cover the pure pieces and the disarmed
    // fast path.

    #[test]
    fn disarmed_hooks_are_inert() {
        assert!(!faults_enabled());
        assert!(before_read_attempt(0, 8).is_ok());
        let mut v = vec![1.0; 4];
        assert!(!corrupt_payload(&mut v));
        assert_eq!(v, vec![1.0; 4]);
        assert_eq!(torn_append(16), None);
        assert!(!commit_crash());
        assert_eq!(lane_wedge(0), None);
        assert_eq!(wal_append_fault(64), None);
        assert!(!quarantine_crash());
        assert_eq!(fake_disk_free(), None);
    }

    #[test]
    fn checksum_detects_a_flipped_bit_and_avoids_the_sentinel() {
        let a: Vec<f64> = (0..64).map(|i| i as f64 * 0.5).collect();
        let mut b = a.clone();
        let ca = checksum(&a);
        assert_eq!(ca, checksum(&b), "checksum is a pure function");
        assert_ne!(ca, 0, "0 is reserved for 'absent'");
        b[17] = f64::from_bits(b[17].to_bits() ^ 1);
        assert_ne!(ca, checksum(&b), "single flipped bit must change the hash");
        assert_ne!(checksum(&[]), 0, "empty payload hashes to non-sentinel");
    }

    #[test]
    fn default_plan_is_inactive_and_default_policy_is_sane() {
        assert!(!FaultPlan::default().active());
        let p = RetryPolicy::default();
        assert!(p.read_retries > 0);
        assert!(p.retry_deadline_ms >= p.retry_backoff_ms);
        assert_eq!(p.backoff(1), Duration::from_millis(p.retry_backoff_ms));
        assert_eq!(p.backoff(2), Duration::from_millis(p.retry_backoff_ms * 2));
        // Backoff is capped by the deadline even for absurd attempts.
        assert!(p.backoff(40) <= Duration::from_millis(p.retry_deadline_ms));
    }

    #[test]
    fn mix_is_deterministic_and_spreads() {
        assert_eq!(mix(42), mix(42));
        assert_ne!(mix(1), mix(2));
    }
}
