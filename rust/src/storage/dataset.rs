//! Dataset directory layout + generation and loading of study sidecar data.
//!
//! A *dataset directory* holds one study:
//!
//! ```text
//! <dir>/
//!   meta.txt        key=value: n, pl, m, block, seed
//!   kinship.bin     M   (n×n f64 LE, col-major)
//!   covariates.bin  X_L (n×pl)
//!   phenotype.bin   y   (n)
//!   xr.xrd          X_R (n×m, blocked — the streamed file)
//!   r.xrd           output (p×m, written by the solvers)
//! ```
//!
//! Generation streams `X_R` block by block so arbitrarily large datasets
//! can be produced in constant memory — the generator is itself
//! out-of-core, like everything in this repo.

use crate::error::{Error, Result};
use crate::gwas::problem::Dims;
use crate::linalg::Matrix;
use crate::storage::format::{f32s_as_bytes, f64s_as_bytes, f64s_as_bytes_mut, Dtype, Header};
use crate::util::XorShift;
use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

/// Study metadata persisted in `meta.txt`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Meta {
    pub dims: Dims,
    pub block: usize,
    pub seed: u64,
}

/// Paths of a dataset directory.
#[derive(Debug, Clone)]
pub struct DatasetPaths {
    pub dir: PathBuf,
}

impl DatasetPaths {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        DatasetPaths { dir: dir.into() }
    }
    pub fn meta(&self) -> PathBuf {
        self.dir.join("meta.txt")
    }
    pub fn kinship(&self) -> PathBuf {
        self.dir.join("kinship.bin")
    }
    pub fn covariates(&self) -> PathBuf {
        self.dir.join("covariates.bin")
    }
    pub fn phenotype(&self) -> PathBuf {
        self.dir.join("phenotype.bin")
    }
    pub fn xr(&self) -> PathBuf {
        self.dir.join("xr.xrd")
    }
    pub fn results(&self) -> PathBuf {
        self.dir.join("r.xrd")
    }
    /// Checkpoint journal: one LE u64 block id per fully-persisted block.
    pub fn progress(&self) -> PathBuf {
        self.dir.join("r.progress")
    }
}

/// Generate a full synthetic dataset on disk (f64 storage).
pub fn generate(dir: &Path, dims: Dims, block: usize, seed: u64) -> Result<Meta> {
    generate_with_dtype(dir, dims, block, seed, Dtype::F64)
}

/// Generate a full synthetic dataset on disk. `X_R` is written blockwise
/// (constant memory in `m`). Deterministic in `seed` and *independent of
/// `block`*: column j's genotypes depend only on (seed, j), so re-chunking
/// the same study produces identical data. `dtype` selects the on-disk
/// element type of `X_R` (the paper's footnote-3 half-storage mode:
/// genotypes are exact small integers, so `F32` is lossless for `X_R`).
pub fn generate_with_dtype(dir: &Path, dims: Dims, block: usize, seed: u64, dtype: Dtype) -> Result<Meta> {
    if block == 0 || block > dims.m {
        return Err(Error::Config(format!("block {block} must be in 1..={}", dims.m)));
    }
    std::fs::create_dir_all(dir).map_err(|e| Error::io(format!("mkdir {}", dir.display()), e))?;
    let paths = DatasetPaths::new(dir);
    let mut rng = XorShift::new(seed);

    // Sidecars (small; in memory).
    let kin = Matrix::rand_spd(dims.n, 4.0, &mut rng);
    write_f64_file(&paths.kinship(), kin.as_slice())?;
    let mut xl = Matrix::randn(dims.n, dims.pl, &mut rng);
    for i in 0..dims.n {
        xl.set(i, 0, 1.0);
    }
    write_f64_file(&paths.covariates(), xl.as_slice())?;

    // X_R blockwise, per-column forked RNG streams for chunking invariance.
    let header = Header::with_dtype(dims.n as u64, dims.m as u64, block as u64, seed, dtype)?;
    let f = File::create(paths.xr()).map_err(|e| Error::io("create xr.xrd", e))?;
    let mut w = BufWriter::with_capacity(1 << 20, f);
    header.write_to(&mut w)?;
    let mut col = vec![0.0f64; dims.n];
    let mut col_seed_rng = XorShift::new(seed ^ 0x5eed_c01);
    let col_base = col_seed_rng.next_u64();
    // Also accumulate the planted-signal contribution of SNP 0 for y.
    let mut snp0 = vec![0.0f64; dims.n];
    for j in 0..dims.m {
        let mut crng = XorShift::new(col_base ^ (j as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let maf = crng.uniform_in(0.05, 0.5);
        for v in col.iter_mut() {
            *v = crng.genotype(maf);
        }
        depolarize(&mut col);
        if j == 0 {
            snp0.copy_from_slice(&col);
        }
        match dtype {
            Dtype::F64 => {
                w.write_all(f64s_as_bytes(&col)).map_err(|e| Error::io("writing xr block", e))?
            }
            Dtype::F32 => {
                let narrow: Vec<f32> = col.iter().map(|&v| v as f32).collect();
                w.write_all(f32s_as_bytes(&narrow))
                    .map_err(|e| Error::io("writing xr block", e))?
            }
        }
    }
    w.flush().map_err(|e| Error::io("flushing xr.xrd", e))?;

    // Phenotype with planted signal (matches Problem::synthetic's recipe).
    let mut y = vec![0.0f64; dims.n];
    for i in 0..dims.n {
        let mut v = 0.3 * snp0[i];
        for k in 0..dims.pl {
            v += 0.1 * xl.get(i, k);
        }
        y[i] = v + rng.normal();
    }
    write_f64_file(&paths.phenotype(), &y)?;

    let meta = Meta { dims, block, seed };
    write_meta(&paths.meta(), &meta)?;
    Ok(meta)
}

/// Load only the study metadata (`meta.txt`) — cheap, no matrix I/O.
/// The service scheduler uses this to estimate a job's host-memory
/// footprint before admitting it.
pub fn load_meta(dir: &Path) -> Result<Meta> {
    read_meta(&DatasetPaths::new(dir).meta())
}

/// Canonical identity of a dataset directory. The service's
/// one-job-per-dataset lock and the shared block cache's keys both use
/// this, so jobs naming one directory through different paths collide
/// on the lock *and* share cache entries — the two rules must never
/// diverge. Falls back to the path as given when it doesn't resolve
/// (e.g. not created yet); such jobs fail later with a clear error.
pub fn canonical_key(dir: &Path) -> PathBuf {
    std::fs::canonicalize(dir).unwrap_or_else(|_| dir.to_path_buf())
}

/// Load the small sidecar data of a dataset (everything except `X_R`).
pub fn load_sidecars(dir: &Path) -> Result<(Meta, Matrix, Matrix, Vec<f64>)> {
    let paths = DatasetPaths::new(dir);
    let meta = read_meta(&paths.meta())?;
    let n = meta.dims.n;
    let kin = Matrix::from_vec(n, n, read_f64_file(&paths.kinship(), n * n)?)?;
    let xl = Matrix::from_vec(n, meta.dims.pl, read_f64_file(&paths.covariates(), n * meta.dims.pl)?)?;
    let y = read_f64_file(&paths.phenotype(), n)?;
    Ok((meta, kin, xl, y))
}

/// Load the whole `X_R` into memory (tests/small studies only).
/// Dtype-aware: F32 files are widened on load.
pub fn load_xr_incore(dir: &Path) -> Result<Matrix> {
    let paths = DatasetPaths::new(dir);
    let f = crate::storage::xrd::XrdFile::open(&paths.xr())?;
    let h = *f.header();
    let mut data = vec![0.0f64; (h.rows * h.cols) as usize];
    f.read_cols_into(0, h.cols, &mut data)?;
    Matrix::from_vec(h.rows as usize, h.cols as usize, data)
}

/// Make a genotype column polymorphic. Real studies drop monomorphic
/// SNPs (a constant column is collinear with the intercept and makes
/// `S_i` singular); the generator instead flips one sample, keeping the
/// column a valid allele-count vector.
fn depolarize(col: &mut [f64]) {
    if let Some(&first) = col.first() {
        if col.iter().all(|&v| v == first) {
            col[0] = if first == 1.0 { 2.0 } else { 1.0 };
        }
    }
}

fn write_meta(path: &Path, meta: &Meta) -> Result<()> {
    let s = format!(
        "n={}\npl={}\nm={}\nblock={}\nseed={}\n",
        meta.dims.n, meta.dims.pl, meta.dims.m, meta.block, meta.seed
    );
    std::fs::write(path, s).map_err(|e| Error::io("writing meta.txt", e))
}

fn read_meta(path: &Path) -> Result<Meta> {
    let s = std::fs::read_to_string(path).map_err(|e| Error::io("reading meta.txt", e))?;
    let mut n = None;
    let mut pl = None;
    let mut m = None;
    let mut block = None;
    let mut seed = None;
    for (lineno, line) in s.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| Error::format(format!("meta.txt line {}: no '='", lineno + 1)))?;
        let v: u64 = v
            .trim()
            .parse()
            .map_err(|_| Error::format(format!("meta.txt: bad value for {k}")))?;
        match k.trim() {
            "n" => n = Some(v),
            "pl" => pl = Some(v),
            "m" => m = Some(v),
            "block" => block = Some(v),
            "seed" => seed = Some(v),
            other => return Err(Error::format(format!("meta.txt: unknown key {other}"))),
        }
    }
    let miss = |k: &str| Error::format(format!("meta.txt: missing key {k}"));
    let dims = Dims::new(
        n.ok_or_else(|| miss("n"))? as usize,
        pl.ok_or_else(|| miss("pl"))? as usize,
        m.ok_or_else(|| miss("m"))? as usize,
    )?;
    Ok(Meta {
        dims,
        block: block.ok_or_else(|| miss("block"))? as usize,
        seed: seed.ok_or_else(|| miss("seed"))?,
    })
}

fn write_f64_file(path: &Path, data: &[f64]) -> Result<()> {
    let f = File::create(path).map_err(|e| Error::io(format!("create {}", path.display()), e))?;
    let mut w = BufWriter::new(f);
    w.write_all(f64s_as_bytes(data)).map_err(|e| Error::io("writing f64 file", e))?;
    w.flush().map_err(|e| Error::io("flush", e))
}

fn read_f64_file(path: &Path, expect: usize) -> Result<Vec<f64>> {
    let mut f = File::open(path).map_err(|e| Error::io(format!("open {}", path.display()), e))?;
    let mut data = vec![0.0f64; expect];
    f.read_exact(f64s_as_bytes_mut(&mut data))
        .map_err(|e| Error::io(format!("reading {} ({expect} f64s)", path.display()), e))?;
    // Reject trailing garbage.
    let mut probe = [0u8; 1];
    match f.read(&mut probe) {
        Ok(0) => Ok(data),
        Ok(_) => Err(Error::format(format!("{} longer than expected", path.display()))),
        Err(e) => Err(Error::io("probing EOF", e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cugwas_test_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn generate_and_load_roundtrip() {
        let dir = tmpdir("roundtrip");
        let dims = Dims::new(20, 3, 11).unwrap();
        let meta = generate(&dir, dims, 4, 77).unwrap();
        assert_eq!(meta.dims, dims);

        let (meta2, kin, xl, y) = load_sidecars(&dir).unwrap();
        assert_eq!(meta, meta2);
        assert_eq!(kin.rows(), 20);
        assert_eq!(xl.cols(), 3);
        assert_eq!(y.len(), 20);
        // Intercept column.
        for i in 0..20 {
            assert_eq!(xl.get(i, 0), 1.0);
        }

        let xr = load_xr_incore(&dir).unwrap();
        assert_eq!(xr.rows(), 20);
        assert_eq!(xr.cols(), 11);
        for v in xr.as_slice() {
            assert!(*v == 0.0 || *v == 1.0 || *v == 2.0);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn generation_is_deterministic_and_block_invariant() {
        let dims = Dims::new(12, 2, 9).unwrap();
        let d1 = tmpdir("det1");
        let d2 = tmpdir("det2");
        generate(&d1, dims, 3, 5).unwrap();
        generate(&d2, dims, 4, 5).unwrap(); // different chunking, same seed
        let x1 = load_xr_incore(&d1).unwrap();
        let x2 = load_xr_incore(&d2).unwrap();
        assert_eq!(x1, x2, "data must not depend on block size");
        let (_, _, _, y1) = load_sidecars(&d1).unwrap();
        let (_, _, _, y2) = load_sidecars(&d2).unwrap();
        assert_eq!(y1, y2);
        std::fs::remove_dir_all(&d1).unwrap();
        std::fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn meta_parser_rejects_garbage() {
        let dir = tmpdir("meta");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("meta.txt");
        std::fs::write(&p, "n=10\npl=2\nm=abc\nblock=2\nseed=0\n").unwrap();
        assert!(read_meta(&p).is_err());
        std::fs::write(&p, "n=10\npl=2\nblock=2\nseed=0\n").unwrap(); // missing m
        assert!(read_meta(&p).is_err());
        std::fs::write(&p, "bogus line\n").unwrap();
        assert!(read_meta(&p).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_block_size_rejected() {
        let dir = tmpdir("badblock");
        let dims = Dims::new(10, 2, 5).unwrap();
        assert!(generate(&dir, dims, 0, 1).is_err());
        assert!(generate(&dir, dims, 6, 1).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_sidecar_is_detected() {
        let dir = tmpdir("trunc");
        let dims = Dims::new(10, 2, 4).unwrap();
        generate(&dir, dims, 2, 3).unwrap();
        // Truncate the phenotype file.
        let p = DatasetPaths::new(&dir).phenotype();
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() - 8]).unwrap();
        assert!(load_sidecars(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
