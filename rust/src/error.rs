//! Crate-wide error type.
//!
//! A single enum covering every failure domain (I/O, format, config,
//! numerics, runtime, pipeline). `anyhow` is reserved for binaries; the
//! library surfaces typed errors so callers can branch on them.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes of the cuGWAS library.
#[derive(Debug)]
pub enum Error {
    /// Underlying OS-level I/O failure, annotated with the operation.
    Io { context: String, source: std::io::Error },
    /// A file did not conform to the XRD / artifact / config format.
    Format(String),
    /// Invalid or inconsistent configuration.
    Config(String),
    /// Numerical failure (e.g. a non-SPD matrix handed to `potrf`).
    Numerical(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Pipeline-level failure (channel closed, drain mismatch).
    Pipeline(String),
    /// A device lane died or wedged mid-stream. Kept distinct from
    /// [`Error::Pipeline`] because it is *recoverable*: the engine
    /// supervisor responds by respawning the lanes and replaying the
    /// segment instead of failing the job.
    LaneFault { lane: usize, msg: String },
    /// Shape/dimension mismatch between operands.
    Shape(String),
    /// The job was stopped cooperatively at a segment boundary (drain,
    /// per-job deadline, or an explicit cancel). Distinct from the
    /// failure variants because the work is *checkpointed*: the journal
    /// holds a durable commit for everything finished, so a later
    /// `resume` continues instead of restarting — the scheduler reports
    /// these jobs as cancelled, not failed.
    Cancelled(String),
}

impl Error {
    /// Attach file/operation context to an `std::io::Error`.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { context: context.into(), source }
    }

    /// Convenience constructor used by parsers.
    pub fn format(msg: impl Into<String>) -> Self {
        Error::Format(msg.into())
    }

    /// Convenience constructor for dimension mismatches.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io { context, source } => write!(f, "io error ({context}): {source}"),
            Error::Format(m) => write!(f, "format error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Pipeline(m) => write!(f, "pipeline error: {m}"),
            Error::LaneFault { lane, msg } => write!(f, "lane {lane} fault: {msg}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Cancelled(m) => write!(f, "cancelled: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io { context: String::new(), source: e }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::io("reading header", std::io::Error::other("boom"));
        let s = e.to_string();
        assert!(s.contains("reading header"), "{s}");
        assert!(s.contains("boom"), "{s}");
    }

    #[test]
    fn from_io_error() {
        let e: Error = std::io::Error::other("x").into();
        assert!(matches!(e, Error::Io { .. }));
    }

    #[test]
    fn source_chain() {
        use std::error::Error as _;
        let e = Error::io("ctx", std::io::Error::other("inner"));
        assert!(e.source().is_some());
        assert!(Error::Format("f".into()).source().is_none());
    }
}
