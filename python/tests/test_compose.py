"""Block-composition properties: the per-block graphs must tile.

The coordinator splits `X_R` into blocks (and blocks into per-lane
chunks); these tests prove at the L2 level that any such partition
composes to the same answer — the mathematical backbone of the streaming
correctness argument.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels.ref import gls_direct_ref
from .conftest import rand_spd


def make_study(n, pl, m, seed=0):
    rng = np.random.default_rng(seed)
    mm = rand_spd(rng, n)
    xl = jnp.asarray(rng.standard_normal((n, pl))).at[:, 0].set(1.0)
    y = jnp.asarray(rng.standard_normal(n))
    xr = jnp.asarray(rng.integers(0, 3, size=(n, m)).astype(np.float64))
    return mm, xl, y, xr


@pytest.mark.parametrize("splits", [[16], [8, 8], [4, 8, 4]])
def test_blockwise_trsm_tiles(splits):
    n, nb, bm = 32, 16, 4
    mm, xl, y, xr = make_study(n, 3, sum(splits), seed=1)
    l, dinv, _, _, _, _ = model.preprocess_entry(mm, xl, y, nb=nb)
    # Whole-matrix solve…
    (whole,) = model.trsm_entry(l, dinv, xr.T, nb=nb, bm=bm)
    # …equals the concatenation of independent block solves.
    parts = []
    c0 = 0
    for w in splits:
        (part,) = model.trsm_entry(l, dinv, xr[:, c0:c0 + w].T, nb=nb, bm=bm)
        parts.append(np.asarray(part))
        c0 += w
    tiled = np.concatenate(parts, axis=0)
    np.testing.assert_allclose(tiled, np.asarray(whole), rtol=0, atol=0)


def test_blockwise_full_pipeline_tiles():
    """blockfull over chunks == direct GLS over the whole study."""
    n, pl, nb, bm = 32, 3, 16, 8
    mm, xl, y, xr = make_study(n, pl, 24, seed=2)
    l, dinv, xlt, yt, stl, rtop = model.preprocess_entry(mm, xl, y, nb=nb)
    parts = []
    for c0 in range(0, 24, 8):
        (r,) = model.blockfull_entry(
            l, dinv, xlt, yt, stl, rtop, xr[:, c0:c0 + 8].T, nb=nb, bm=bm
        )
        parts.append(np.asarray(r))
    tiled = np.concatenate(parts, axis=0).T  # (p, m)
    want = gls_direct_ref(mm, xl, y, xr)
    np.testing.assert_allclose(tiled, np.asarray(want), rtol=1e-6, atol=1e-8)


def test_zero_padded_tail_columns_do_not_corrupt_live_ones():
    """The coordinator zero-pads ragged tails to the artifact width; the
    live columns' results must be unaffected by the padding."""
    n, pl, nb, bm = 32, 3, 16, 8
    mm, xl, y, xr = make_study(n, pl, 8, seed=3)
    l, dinv, xlt, yt, _, _ = model.preprocess_entry(mm, xl, y, nb=nb)
    # Full 8 columns.
    full, g_full, rb_full, d_full = model.block_entry(l, dinv, xlt, yt, xr.T, nb=nb, bm=bm)
    # 5 live + 3 zero columns.
    padded = jnp.concatenate([xr[:, :5], jnp.zeros((n, 3))], axis=1)
    part, g_part, rb_part, d_part = model.block_entry(l, dinv, xlt, yt, padded.T, nb=nb, bm=bm)
    np.testing.assert_allclose(np.asarray(part)[:5], np.asarray(full)[:5], rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(g_part)[:5], np.asarray(g_full)[:5], rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(rb_part)[:5], np.asarray(rb_full)[:5], rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(d_part)[:5], np.asarray(d_full)[:5], rtol=0, atol=0)
    # Padded columns produce exactly zero reductions.
    assert np.all(np.asarray(d_part)[5:] == 0)
    assert np.all(np.asarray(rb_part)[5:] == 0)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 20),
    cut=st.integers(1, 19),
    seed=st.integers(0, 2**16),
)
def test_any_two_way_split_tiles(m, cut, seed):
    if cut >= m:
        return
    n, pl, nb, bm = 16, 2, 8, 1
    mm, xl, y, xr = make_study(n, pl, m, seed=seed)
    l, dinv, xlt, yt, stl, rtop = model.preprocess_entry(mm, xl, y, nb=nb)
    (whole,) = model.blockfull_entry(l, dinv, xlt, yt, stl, rtop, xr.T, nb=nb, bm=bm)
    (a,) = model.blockfull_entry(l, dinv, xlt, yt, stl, rtop, xr[:, :cut].T, nb=nb, bm=bm)
    (b,) = model.blockfull_entry(l, dinv, xlt, yt, stl, rtop, xr[:, cut:].T, nb=nb, bm=bm)
    tiled = np.concatenate([np.asarray(a), np.asarray(b)], axis=0)
    np.testing.assert_allclose(tiled, np.asarray(whole), rtol=1e-12, atol=1e-12)
