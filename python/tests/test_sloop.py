"""L1 fused S-loop reduction kernel vs the pure-jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import sloop_reduce
from compile.kernels.ref import sloop_reduce_ref


def run_case(n, pl, mb, bm, seed=0, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    xlt = jnp.asarray(rng.standard_normal((n, pl)), dtype=dtype)
    yt = jnp.asarray(rng.standard_normal(n), dtype=dtype)
    xbt = jnp.asarray(rng.standard_normal((n, mb)), dtype=dtype)
    got = sloop_reduce(xlt, yt, xbt, bm=bm)
    want = sloop_reduce_ref(xlt, yt, xbt)
    return got, want


@pytest.mark.parametrize(
    "n,pl,mb,bm",
    [
        (16, 1, 8, 8),
        (64, 3, 32, 16),
        (64, 3, 64, 32),
        (128, 5, 48, 16),
        (256, 3, 128, 64),
    ],
)
def test_sloop_matches_ref(n, pl, mb, bm):
    (g, rb, d), (g0, rb0, d0) = run_case(n, pl, mb, bm)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0), rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(np.asarray(rb), np.asarray(rb0), rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d0), rtol=1e-10, atol=1e-10)


def test_sloop_d_is_nonnegative():
    (_, _, d), _ = run_case(32, 2, 16, 8, seed=9)
    assert np.all(np.asarray(d) >= 0)


def test_sloop_zero_block():
    got, _ = run_case(16, 2, 8, 8)
    g, rb, d = sloop_reduce(jnp.zeros((16, 2)), jnp.zeros(16), jnp.zeros((16, 8)), bm=8)
    assert np.all(np.asarray(g) == 0)
    assert np.all(np.asarray(rb) == 0)
    assert np.all(np.asarray(d) == 0)


def test_sloop_rejects_misaligned_tile():
    with pytest.raises(ValueError):
        sloop_reduce(jnp.zeros((16, 2)), jnp.zeros(16), jnp.zeros((16, 10)), bm=4)


def test_sloop_float32():
    (g, rb, d), (g0, rb0, d0) = run_case(32, 3, 16, 8, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rb), np.asarray(rb0), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d0), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([16, 32, 64]),
    pl=st.integers(1, 6),
    tiles=st.integers(1, 3),
    bm=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**20),
)
def test_sloop_hypothesis(n, pl, tiles, bm, seed):
    mb = tiles * bm
    (g, rb, d), (g0, rb0, d0) = run_case(n, pl, mb, bm, seed=seed)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(rb), np.asarray(rb0), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d0), rtol=1e-9, atol=1e-9)
