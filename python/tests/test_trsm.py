"""L1 trsm kernel vs the pure-jnp oracle (plus hypothesis shape sweeps)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import invert_diag_blocks, trsm_blocked
from compile.kernels.ref import trsm_ref
from .conftest import rand_lower


def run_case(n, mb, nb, bm, seed=0, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    l = rand_lower(rng, n, dtype)
    b = jnp.asarray(rng.standard_normal((n, mb)), dtype=dtype)
    dinv = invert_diag_blocks(l, nb)
    got = trsm_blocked(l, dinv, b, nb=nb, bm=bm)
    want = trsm_ref(l, b)
    return np.asarray(got), np.asarray(want)


@pytest.mark.parametrize(
    "n,mb,nb,bm",
    [
        (16, 8, 16, 8),    # single diagonal block, single column tile
        (32, 8, 16, 8),    # two diagonal blocks
        (64, 32, 16, 16),  # the shipped small artifact shape
        (64, 64, 16, 32),
        (128, 48, 32, 16), # three column tiles
        (96, 16, 32, 16),
    ],
)
def test_trsm_matches_ref(n, mb, nb, bm):
    got, want = run_case(n, mb, nb, bm)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_trsm_residual_is_small():
    # Independent of the oracle: check L @ X == B directly.
    rng = np.random.default_rng(3)
    n, mb, nb, bm = 64, 32, 16, 16
    l = rand_lower(rng, n)
    b = jnp.asarray(rng.standard_normal((n, mb)))
    x = trsm_blocked(l, invert_diag_blocks(l, nb), b, nb=nb, bm=bm)
    np.testing.assert_allclose(np.asarray(l @ x), np.asarray(b), rtol=1e-9, atol=1e-9)


def test_trsm_identity_l():
    n, mb, nb, bm = 32, 16, 16, 16
    l = jnp.eye(n)
    b = jnp.arange(n * mb, dtype=jnp.float64).reshape(n, mb)
    x = trsm_blocked(l, invert_diag_blocks(l, nb), b, nb=nb, bm=bm)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(b))


def test_trsm_rejects_misaligned_shapes():
    rng = np.random.default_rng(0)
    l = rand_lower(rng, 48)
    dinv = invert_diag_blocks(l, 16)
    b = jnp.zeros((48, 10))
    with pytest.raises(ValueError):
        trsm_blocked(l, dinv, b, nb=16, bm=4)  # mb % bm != 0
    with pytest.raises(ValueError):
        trsm_blocked(l, dinv, jnp.zeros((48, 8)), nb=20, bm=8)  # n % nb != 0
    with pytest.raises(ValueError):
        invert_diag_blocks(l, 20)


def test_trsm_float32():
    got, want = run_case(32, 16, 16, 8, dtype=jnp.float32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    nblocks=st.integers(1, 4),
    nb_pow=st.sampled_from([8, 16]),
    tiles=st.integers(1, 3),
    bm=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**20),
)
def test_trsm_hypothesis_shapes(nblocks, nb_pow, tiles, bm, seed):
    n = nblocks * nb_pow
    mb = tiles * bm
    got, want = run_case(n, mb, nb_pow, bm, seed=seed)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_invert_diag_blocks_shape_and_value():
    rng = np.random.default_rng(5)
    l = rand_lower(rng, 32)
    dinv = invert_diag_blocks(l, 16)
    assert dinv.shape == (32, 16)
    for k in range(2):
        blk = np.asarray(l)[k * 16:(k + 1) * 16, k * 16:(k + 1) * 16]
        inv = np.asarray(dinv)[k * 16:(k + 1) * 16, :]
        np.testing.assert_allclose(inv @ blk, np.eye(16), rtol=1e-10, atol=1e-10)
