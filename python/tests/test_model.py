"""L2 model graphs vs the definition-level GLS oracle."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.kernels.ref import gls_direct_ref, solve_rs_ref
from .conftest import rand_spd


def make_study(n, pl, m, seed=0):
    rng = np.random.default_rng(seed)
    mm = rand_spd(rng, n)
    xl = jnp.asarray(rng.standard_normal((n, pl)))
    xl = xl.at[:, 0].set(1.0)
    y = jnp.asarray(rng.standard_normal(n))
    xr = jnp.asarray(rng.integers(0, 3, size=(n, m)).astype(np.float64))
    return mm, xl, y, xr


def test_preprocess_entry_invariants():
    n, pl, nb = 32, 3, 16
    mm, xl, y, _ = make_study(n, pl, 4)
    l, dinv, xlt, yt, stl, rtop = model.preprocess_entry(mm, xl, y, nb=nb)
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(mm), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(l @ xlt), np.asarray(xl), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(l @ yt), np.asarray(y), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(stl), np.asarray(xlt.T @ xlt), rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(rtop), np.asarray(xlt.T @ yt), rtol=1e-9, atol=1e-9)
    assert dinv.shape == (n, nb)


@pytest.mark.parametrize("n,pl,mb,nb,bm", [(32, 3, 16, 16, 8), (64, 3, 32, 16, 16)])
def test_blockfull_matches_direct_gls(n, pl, mb, nb, bm):
    """End-to-end: full-offload graph == definition-level GLS."""
    mm, xl, y, xr = make_study(n, pl, mb, seed=4)
    l, dinv, xlt, yt, stl, rtop = model.preprocess_entry(mm, xl, y, nb=nb)
    (r_rows,) = model.blockfull_entry(l, dinv, xlt, yt, stl, rtop, xr.T, nb=nb, bm=bm)
    want = gls_direct_ref(mm, xl, y, xr)  # (p, mb)
    np.testing.assert_allclose(np.asarray(r_rows.T), np.asarray(want), rtol=1e-6, atol=1e-8)


def test_block_entry_composes_with_solve_rs():
    """Fused-mode outputs + CPU-side solve == full-offload output."""
    n, pl, mb, nb, bm = 32, 3, 16, 16, 8
    mm, xl, y, xr = make_study(n, pl, mb, seed=5)
    l, dinv, xlt, yt, stl, rtop = model.preprocess_entry(mm, xl, y, nb=nb)
    xbt_rows, g_rows, rb, d = model.block_entry(l, dinv, xlt, yt, xr.T, nb=nb, bm=bm)
    r = solve_rs_ref(stl, rtop, g_rows.T, rb, d)
    (r_full_rows,) = model.blockfull_entry(l, dinv, xlt, yt, stl, rtop, xr.T, nb=nb, bm=bm)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_full_rows.T), rtol=1e-8, atol=1e-10)


def test_trsm_entry_matches_block_entry_xbt():
    n, pl, mb, nb, bm = 32, 3, 16, 16, 8
    mm, xl, y, xr = make_study(n, pl, mb, seed=6)
    l, dinv, xlt, yt, _, _ = model.preprocess_entry(mm, xl, y, nb=nb)
    (xbt1,) = model.trsm_entry(l, dinv, xr.T, nb=nb, bm=bm)
    xbt2, _, _, _ = model.block_entry(l, dinv, xlt, yt, xr.T, nb=nb, bm=bm)
    np.testing.assert_allclose(np.asarray(xbt1), np.asarray(xbt2), rtol=0, atol=0)


def test_row_major_contract():
    """xb_rows really is interpreted as the transposed block."""
    n, mb, nb, bm = 32, 16, 16, 8
    rng = np.random.default_rng(7)
    l = jnp.eye(n)  # identity ⇒ output == input
    dinv = model.invert_diag_blocks(l, nb)
    xb = jnp.asarray(rng.standard_normal((n, mb)))
    (out_rows,) = model.trsm_entry(l, dinv, xb.T, nb=nb, bm=bm)
    np.testing.assert_array_equal(np.asarray(out_rows), np.asarray(xb.T))
