"""AOT lowering: every variant produces loadable, custom-call-free HLO
text, and the new in-graph Cholesky paths match the library ones."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import aot, model
from .conftest import rand_spd


@pytest.mark.parametrize("kind", aot.KINDS)
def test_lowering_emits_parseable_hlo(kind):
    text = aot.lower_variant(kind, 64, 3, 32, 16, 16)
    assert "HloModule" in text
    assert len(text) > 500


@pytest.mark.parametrize("kind", aot.KINDS)
def test_no_custom_calls_in_artifacts(kind):
    """xla_extension 0.5.1 rejects typed-FFI custom-calls (LAPACK etc.);
    every artifact must lower to pure HLO ops."""
    text = aot.lower_variant(kind, 64, 3, 32, 16, 16)
    assert "custom-call" not in text, f"{kind} artifact contains a custom-call"


def test_chol_in_graph_matches_linalg():
    rng = np.random.default_rng(0)
    m = rand_spd(rng, 48)
    got = model.chol_in_graph(m)
    want = jnp.linalg.cholesky(m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-10)


def test_batched_chol_small_matches_linalg():
    rng = np.random.default_rng(1)
    s = np.stack([np.asarray(rand_spd(rng, 4)) for _ in range(6)])
    got = model.batched_chol_small(jnp.asarray(s))
    want = np.linalg.cholesky(s)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-10, atol=1e-10)


def test_solve_rs_inline_matches_ref():
    from compile.kernels.ref import solve_rs_ref

    rng = np.random.default_rng(2)
    pl, mb = 3, 8
    stl = jnp.asarray(np.asarray(rand_spd(rng, pl)) * 2)
    rtop = jnp.asarray(rng.standard_normal(pl))
    g = jnp.asarray(rng.standard_normal((pl, mb)) * 0.1)
    rb = jnp.asarray(rng.standard_normal(mb))
    d = jnp.asarray(rng.uniform(5.0, 9.0, mb))
    got = model.solve_rs_inline(stl, rtop, g, rb, d)
    want = solve_rs_ref(stl, rtop, g, rb, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-9, atol=1e-9)


def test_profiles_are_well_formed():
    for name, shapes in aot.PROFILES.items():
        for (n, pl, mb, nb, bm) in shapes:
            assert n % nb == 0, f"{name}: n={n} nb={nb}"
            assert mb % bm == 0, f"{name}: mb={mb} bm={bm}"
            assert pl >= 1


def test_build_writes_manifest(tmp_path):
    aot.build(str(tmp_path), "small")
    manifest = (tmp_path / "manifest.tsv").read_text()
    lines = [l for l in manifest.splitlines() if l and not l.startswith("#")]
    # small profile: 2 shapes × 4 kinds − 1 deduped preprocess = 7
    assert len(lines) == 7
    for line in lines:
        fields = line.split("\t")
        assert len(fields) == 8
        assert (tmp_path / fields[7]).exists()
