"""Shared fixtures/helpers for the cuGWAS python test suite."""

import os
import sys

# Make `compile` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np


def rand_lower(rng, n, dtype=jnp.float64):
    """A well-conditioned lower-triangular factor (as potrf would give)."""
    a = rng.standard_normal((n, n))
    l = np.tril(a)
    l[np.diag_indices(n)] = 2.0 + np.abs(l[np.diag_indices(n)])
    return jnp.asarray(l, dtype=dtype)


def rand_spd(rng, n, dtype=jnp.float64):
    a = rng.standard_normal((n, n))
    return jnp.asarray(a @ a.T / n + 4.0 * np.eye(n), dtype=dtype)
