"""AOT lowering: JAX entry points → HLO text artifacts + manifest.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. All computations are lowered with
``return_tuple=True`` and unwrapped with ``to_tuple()`` on the rust side.

Usage::

    python -m compile.aot --out-dir ../artifacts [--profile small|default|full]

Artifacts: ``<kind>_n{n}_pl{pl}_mb{mb}_nb{nb}_bm{bm}.hlo.txt`` plus
``manifest.tsv`` with one line per artifact::

    kind  n  pl  mb  nb  bm  dtype  filename

The rust runtime (``rust/src/runtime/artifact.rs``) selects artifacts by
(kind, shape) from the manifest.
"""

import argparse
import functools
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F64 = jnp.float64


def to_hlo_text(lowered):
    """Lowered jax → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F64)


def lower_variant(kind, n, pl, mb, nb, bm):
    """Lower one (kind, shape) variant; returns HLO text."""
    if kind == "preprocess":
        fn = functools.partial(model.preprocess_entry, nb=nb)
        args = (spec(n, n), spec(n, pl), spec(n))
    elif kind == "trsm":
        fn = functools.partial(model.trsm_entry, nb=nb, bm=bm)
        args = (spec(n, n), spec(n, nb), spec(mb, n))
    elif kind == "block":
        fn = functools.partial(model.block_entry, nb=nb, bm=bm)
        args = (spec(n, n), spec(n, nb), spec(n, pl), spec(n), spec(mb, n))
    elif kind == "blockfull":
        fn = functools.partial(model.blockfull_entry, nb=nb, bm=bm)
        args = (
            spec(n, n), spec(n, nb), spec(n, pl), spec(n),
            spec(pl, pl), spec(pl), spec(mb, n),
        )
    else:
        raise ValueError(f"unknown kind {kind}")
    return to_hlo_text(jax.jit(fn).lower(*args))


# (n, pl, mb, nb, bm) shape tuples per profile. Constraints: n % nb == 0,
# mb % bm == 0. The "small" shapes keep `make artifacts` + the rust test
# suite fast; "default" adds the shapes the examples and benches use.
PROFILES = {
    "small": [
        (64, 3, 32, 16, 16),
        (64, 3, 64, 16, 32),
    ],
    "default": [
        (64, 3, 32, 16, 16),
        (64, 3, 64, 16, 32),
        (256, 3, 128, 32, 64),
        (512, 3, 256, 64, 128),
    ],
    "full": [
        (64, 3, 32, 16, 16),
        (64, 3, 64, 16, 32),
        (256, 3, 128, 32, 64),
        (512, 3, 256, 64, 128),
        (1024, 3, 512, 64, 128),
        (2048, 3, 512, 64, 128),
    ],
}

KINDS = ["preprocess", "trsm", "block", "blockfull"]


def build(out_dir, profile):
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    shapes = PROFILES[profile]
    total = len(shapes) * len(KINDS)
    done = 0
    seen = set()
    for (n, pl, mb, nb, bm) in shapes:
        for kind in KINDS:
            # The preprocess graph does not depend on (mb, bm): emit it once
            # per (n, pl, nb) so the manifest stays duplicate-free.
            key = (kind, n, pl, 0 if kind == "preprocess" else mb)
            if key in seen:
                done += 1
                continue
            seen.add(key)
            name = f"{kind}_n{n}_pl{pl}_mb{mb}_nb{nb}_bm{bm}.hlo.txt"
            path = os.path.join(out_dir, name)
            text = lower_variant(kind, n, pl, mb, nb, bm)
            with open(path, "w") as f:
                f.write(text)
            manifest_lines.append(
                f"{kind}\t{n}\t{pl}\t{mb}\t{nb}\t{bm}\tf64\t{name}"
            )
            done += 1
            print(f"[{done}/{total}] {name} ({len(text)} chars)", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("# kind\tn\tpl\tmb\tnb\tbm\tdtype\tfile\n")
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {total} artifacts + manifest to {out_dir}", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", choices=sorted(PROFILES), default="default")
    args = ap.parse_args()
    build(args.out_dir, args.profile)


if __name__ == "__main__":
    main()
