"""Layer-2 JAX compute graphs — the per-block GWAS math the accelerator
executes, composed from the Layer-1 Pallas kernels.

Buffer-layout contract with the rust runtime (see ``rust/src/runtime``):
XLA literals built from flat buffers are **row-major**, while the rust
coordinator's natural layouts are column-major (one SNP = one contiguous
column, straight off disk). Every entry point therefore speaks
"SNP-rows": a block travels as ``xb_rows`` of shape ``(mb, n)`` whose
row-major image *is* the disk image of the column-major ``(n, mb)`` block.
Outputs follow the same convention (``xbt_rows``, ``g_rows``, ``r_rows``),
so the rust side never transposes on the hot path; the transposes below
are resolved by XLA's layout assignment, not materialized.

Entry points (all AOT-lowered by ``aot.py``):

* :func:`preprocess_entry`  — Listing 1.1 lines 1–5 + ``Dinv`` (once/study)
* :func:`trsm_entry`        — pure paper mode: device does only the trsm
* :func:`block_entry`       — fused mode: trsm + S-loop reductions
* :func:`blockfull_entry`   — full-offload ablation: block → ``r`` directly
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import jax.scipy.linalg as jsl

from .kernels import invert_diag_blocks, sloop_reduce, trsm_blocked
from .kernels.trsm import solve_lower_in_graph


def chol_in_graph(m):
    """Lower-Cholesky without LAPACK custom-calls.

    ``jnp.linalg.cholesky`` lowers to a typed-FFI custom-call on CPU, which
    the runtime's xla_extension 0.5.1 rejects (see aot.py header). This
    right-looking rank-1 formulation lowers to pure HLO (`fori_loop` →
    `while`), is O(n³) like potrf, and runs once per study.
    """
    n = m.shape[0]
    idx = jnp.arange(n)

    def body(j, a):
        pivot = jnp.sqrt(a[j, j])
        col = jnp.where(idx >= j, a[:, j] / pivot, 0.0).at[j].set(pivot)
        trailing = (idx[:, None] > j) & (idx[None, :] > j)
        a = a - jnp.where(trailing, jnp.outer(col, col), 0.0)
        return a.at[:, j].set(col)

    return jnp.tril(jax.lax.fori_loop(0, n, body, m))


def batched_chol_small(s):
    """Batched Cholesky of tiny SPD systems, unrolled over the static size.

    ``s`` is ``(mb, p, p)`` with p ≤ ~20 (the paper's covariate count), so
    a trace-time unrolled loop beats any library call and, crucially,
    avoids the LAPACK custom-call (see :func:`chol_in_graph`).
    """
    p = s.shape[-1]
    l = jnp.zeros_like(s)
    for j in range(p):
        d = s[:, j, j] - jnp.sum(l[:, j, :j] * l[:, j, :j], axis=-1)
        dj = jnp.sqrt(d)
        l = l.at[:, j, j].set(dj)
        for i in range(j + 1, p):
            v = s[:, i, j] - jnp.sum(l[:, i, :j] * l[:, j, :j], axis=-1)
            l = l.at[:, i, j].set(v / dj)
    return l


def solve_rs_inline(stl, rtop, g, rb, d):
    """Custom-call-free batched per-SNP assembly + SPD solve.

    Same math as ``kernels.ref.solve_rs_ref`` (which the tests compare
    against) but with the unrolled Cholesky + substitutions, so the
    blockfull artifact compiles on the 0.5.1 runtime.
    """
    pl_, mb = g.shape
    p = pl_ + 1
    s = jnp.zeros((mb, p, p), dtype=g.dtype)
    s = s.at[:, :pl_, :pl_].set(stl[None, :, :])
    s = s.at[:, :pl_, pl_].set(g.T)
    s = s.at[:, pl_, :pl_].set(g.T)
    s = s.at[:, pl_, pl_].set(d)
    rhs = jnp.concatenate([jnp.broadcast_to(rtop, (mb, pl_)), rb[:, None]], axis=1)
    l = batched_chol_small(s)
    # Forward substitution L z = rhs (unrolled).
    z = jnp.zeros_like(rhs)
    for i in range(p):
        acc = rhs[:, i] - jnp.sum(l[:, i, :i] * z[:, :i], axis=-1)
        z = z.at[:, i].set(acc / l[:, i, i])
    # Backward substitution L^T x = z.
    x = jnp.zeros_like(z)
    for i in reversed(range(p)):
        acc = z[:, i] - jnp.sum(l[:, i + 1:, i] * x[:, i + 1:], axis=-1)
        x = x.at[:, i].set(acc / l[:, i, i])
    return x.T  # (p, mb)


def preprocess_entry(m, xl, y, *, nb):
    """Study preprocessing: ``L, Dinv, X̃_L, ỹ, S_TL, r̃_T``.

    Runs once (seconds, per the paper) — plain jnp, no Pallas.
    ``n`` must be a multiple of ``nb`` (aot.py only emits such variants).
    """
    l = chol_in_graph(m)                             # potrf
    dinv = invert_diag_blocks(l, nb)
    xlt = solve_lower_in_graph(l, xl)                # trsm
    yt = solve_lower_in_graph(l, y[:, None])[:, 0]   # trsv
    rtop = xlt.T @ yt                                # gemv
    stl = xlt.T @ xlt                                # syrk
    return l, dinv, xlt, yt, stl, rtop


def trsm_entry(l, dinv, xb_rows, *, nb, bm):
    """Device trsm only (the paper's exact GPU work): ``X̃_b = L^-1 X_b``."""
    xbt = trsm_blocked(l, dinv, xb_rows.T, nb=nb, bm=bm)
    return (xbt.T,)


def block_entry(l, dinv, xlt, yt, xb_rows, *, nb, bm):
    """Fused device block: trsm + single-pass S-loop reductions.

    Returns ``(xbt_rows, g_rows, rb, d)`` — everything the CPU needs to
    finish the S-loop with tiny per-SNP ``posv`` solves.
    """
    xbt = trsm_blocked(l, dinv, xb_rows.T, nb=nb, bm=bm)
    g, rb, d = sloop_reduce(xlt, yt, xbt, bm=bm)
    return xbt.T, g.T, rb, d


def blockfull_entry(l, dinv, xlt, yt, stl, rtop, xb_rows, *, nb, bm):
    """Full offload: the device returns the per-SNP solutions ``r`` alone.

    Ablation target — the paper keeps this half on the CPU to overlap it
    with the next block's trsm; this graph lets the benches measure what
    full offload would cost instead.
    """
    xbt = trsm_blocked(l, dinv, xb_rows.T, nb=nb, bm=bm)
    g, rb, d = sloop_reduce(xlt, yt, xbt, bm=bm)
    r = solve_rs_inline(stl, rtop, g, rb, d)         # batched assembly+posv
    return (r.T,)                                    # (mb, p) row-major
