"""Layer-1 Pallas kernels for cuGWAS-rs.

Two kernels cover the paper's per-block hot path:

* :mod:`.trsm` — blocked triangular solve ``X̃_b = L^-1 X_b`` (the paper's
  accelerator bottleneck, Listing 1.2 line 10 / Listing 1.3 line 11).
* :mod:`.sloop` — the fused S-loop reductions ``G = X̃_L^T X̃_b``,
  ``rb = X̃_b^T ỹ``, ``d_j = ‖x̃_j‖²`` in a single pass over ``X̃_b``.

Both are authored for TPU-style tiling (VMEM blocks, matmul-only inner
loops for the MXU) but lowered with ``interpret=True`` so the AOT HLO runs
on the CPU PJRT client. :mod:`.ref` holds the pure-jnp oracles.
"""

from . import ref
from .sloop import sloop_reduce
from .trsm import invert_diag_blocks, trsm_blocked

__all__ = ["ref", "sloop_reduce", "trsm_blocked", "invert_diag_blocks"]
