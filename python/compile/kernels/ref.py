"""Pure-jnp correctness oracles for the Layer-1 kernels.

These are the ground truth the pytest/hypothesis suites compare the Pallas
kernels against, and double as readable documentation of what each kernel
computes. No Pallas, no tiling — just the math.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import jax.scipy.linalg as jsl


def trsm_ref(l, b):
    """Solve ``L X = B`` for lower-triangular ``L`` (the paper's trsm).

    Args:
      l: (n, n) lower-triangular Cholesky factor.
      b: (n, mb) right-hand sides (one SNP per column).

    Returns:
      (n, mb) solution ``L^-1 B``.
    """
    return jsl.solve_triangular(l, b, lower=True)


def sloop_reduce_ref(xlt, yt, xbt):
    """Fused S-loop reductions over a solved block ``X̃_b``.

    Args:
      xlt: (n, pl) preprocessed covariates ``X̃_L``.
      yt:  (n,) preprocessed phenotype ``ỹ``.
      xbt: (n, mb) solved block ``X̃_b``.

    Returns:
      g:  (pl, mb) — ``X̃_L^T X̃_b``  (paper's per-SNP ``S_BL``, batched)
      rb: (mb,)    — ``X̃_b^T ỹ``    (paper's per-SNP ``r̃_B``)
      d:  (mb,)    — column squared norms (paper's per-SNP ``S_BR``)
    """
    g = xlt.T @ xbt
    rb = xbt.T @ yt
    d = jnp.sum(xbt * xbt, axis=0)
    return g, rb, d


def solve_rs_ref(stl, rtop, g, rb, d):
    """Per-SNP assembly + SPD solve (paper Listing 1.1 line 11, batched).

    Builds, for every SNP column j::

        S_j = [[S_TL, g_j], [g_j^T, d_j]],   rhs_j = [r̃_T, rb_j]

    and returns ``r_j = S_j^-1 rhs_j`` stacked as (p, mb).
    """
    pl_, mb = g.shape
    p = pl_ + 1
    s = jnp.zeros((mb, p, p), dtype=g.dtype)
    s = s.at[:, :pl_, :pl_].set(stl[None, :, :])
    s = s.at[:, :pl_, pl_].set(g.T)
    s = s.at[:, pl_, :pl_].set(g.T)
    s = s.at[:, pl_, pl_].set(d)
    rhs = jnp.concatenate([jnp.broadcast_to(rtop, (mb, pl_)), rb[:, None]], axis=1)
    chol = jnp.linalg.cholesky(s)
    z = jsl.solve_triangular(chol, rhs[..., None], lower=True)
    r = jsl.solve_triangular(jnp.swapaxes(chol, -1, -2), z, lower=False)
    return r[..., 0].T  # (p, mb)


def gls_direct_ref(m, xl, y, xr):
    """Definition-level GLS solve for every SNP (tiny sizes only).

    ``r_i = (X_i^T M^-1 X_i)^-1 X_i^T M^-1 y`` with ``X_i = [X_L | xr_i]``.
    The end-to-end oracle for the whole model pipeline.
    """
    minv = jnp.linalg.inv(m)

    def solve_one(xri):
        x = jnp.concatenate([xl, xri[:, None]], axis=1)
        s = x.T @ minv @ x
        rhs = x.T @ minv @ y
        return jnp.linalg.solve(s, rhs)

    return jax.vmap(solve_one, in_axes=1, out_axes=1)(xr)
