"""Blocked triangular solve ``X̃_b = L^-1 X_b`` as a Pallas kernel.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper calls
cuBLAS ``dtrsm`` on a Fermi GPU. A literal port (per-row forward
substitution in the kernel) would serialize on the TPU's vector units and
starve the MXU. Instead we use the same trick high-performance GPU trsm
implementations use internally — *invert the diagonal blocks up front*
(once, at preprocess time, O(n·nb²)) so the streaming inner loop is pure
matmul:

    for k in 0..nblocks:
        acc   = B[k] - Σ_{j<k} L[k,j] @ X[j]      # rank-nb updates, MXU
        X[k]  = Dinv[k] @ acc                     # nb×nb matmul, MXU

The kernel is gridded over RHS column tiles (one SNP stripe per program
instance); ``L`` row-stripes and ``Dinv`` blocks stream through VMEM. The
sequential k-loop carries no data between grid programs, so column tiles
parallelize perfectly — the analogue of the paper splitting the trsm
across GPUs by columns.

VMEM budget per program (f64): column tile ``n×bm`` in/out (2·n·bm·8 B),
one ``nb×n`` L stripe, one ``nb×nb`` Dinv block. For the shipped artifact
shapes (n ≤ 2048, bm = 128, nb = 64) that is ≤ 4.6 MiB — inside the
16 MiB VMEM of a TPU core with room for double-buffering.
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.experimental import pallas as pl


def solve_lower_in_graph(l, b):
    """Forward substitution ``L^-1 B`` without LAPACK custom-calls.

    ``jax.scipy.linalg.solve_triangular`` lowers to a typed-FFI
    ``lapack_dtrsm`` call on CPU, which the runtime's xla_extension 0.5.1
    rejects; this masked row-sweep lowers to pure HLO (`while` + dots).
    Cold path only (preprocessing) — the hot path is the Pallas kernel.
    """
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(i, x):
        row = jnp.where(idx < i, l[i, :], 0.0)
        acc = b[i] - row @ x
        return x.at[i].set(acc / l[i, i])

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def invert_diag_blocks(l, nb):
    """Invert the ``nb×nb`` diagonal blocks of lower-triangular ``l``.

    Returns a ``(nblocks*nb, nb)`` stack (block k at rows ``k*nb:(k+1)*nb``).
    Runs once per study in the preprocess graph — not on the hot path.
    ``n`` must be a multiple of ``nb`` (the L2 layer pads otherwise).
    """
    n = l.shape[0]
    if n % nb != 0:
        raise ValueError(f"n={n} must be a multiple of nb={nb}")
    nblocks = n // nb
    blocks = jnp.stack([l[k * nb:(k + 1) * nb, k * nb:(k + 1) * nb] for k in range(nblocks)])
    eye = jnp.eye(nb, dtype=l.dtype)
    inv = jax.vmap(lambda blk: solve_lower_in_graph(blk, eye))(blocks)
    return inv.reshape(nblocks * nb, nb)


def _trsm_kernel(l_ref, dinv_ref, b_ref, o_ref, *, nb, nblocks):
    """One column stripe: blocked forward substitution, matmul-only.

    Both loops are *static* (``nblocks`` is trace-time), so they unroll:
    no `while` ops, no dynamic slices — XLA sees a straight-line chain of
    `dot`s it can schedule and fuse. §Perf: the unrolled form cut the
    per-block device time ~22 % at n=512 vs the original `fori_loop`
    version (see EXPERIMENTS.md). The carried solution tiles live in
    registers/VMEM (`xs`), written back once per row block.
    """
    xs = []
    for k in range(nblocks):
        row0 = k * nb
        acc = b_ref[row0:row0 + nb, :]
        for j in range(k):
            lkj = l_ref[row0:row0 + nb, j * nb:(j + 1) * nb]
            acc = acc - lkj @ xs[j]
        xk = dinv_ref[row0:row0 + nb, :] @ acc
        xs.append(xk)
        o_ref[row0:row0 + nb, :] = xk


@functools.partial(jax.jit, static_argnames=("nb", "bm"))
def trsm_blocked(l, dinv, b, *, nb=64, bm=128):
    """Solve ``L X = B`` with inverted diagonal blocks ``dinv``.

    Args:
      l:    (n, n) lower-triangular factor. ``n % nb == 0``.
      dinv: (n, nb) stacked inverted diagonal blocks
            (from :func:`invert_diag_blocks`).
      b:    (n, mb) right-hand sides. ``mb % bm == 0``.
      nb:   diagonal block size (static).
      bm:   RHS column tile per grid program (static).

    Returns:
      (n, mb) solution.
    """
    n, mb = b.shape
    if n % nb != 0:
        raise ValueError(f"n={n} not a multiple of nb={nb}")
    if mb % bm != 0:
        raise ValueError(f"mb={mb} not a multiple of bm={bm}")
    nblocks = n // nb
    grid = (mb // bm,)
    return pl.pallas_call(
        functools.partial(_trsm_kernel, nb=nb, nblocks=nblocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),        # L: full, shared
            pl.BlockSpec((n, nb), lambda i: (0, 0)),        # Dinv: full, shared
            pl.BlockSpec((n, bm), lambda i: (0, i)),        # B: one column tile
        ],
        out_specs=pl.BlockSpec((n, bm), lambda i: (0, i)),  # X: same tile
        out_shape=jax.ShapeDtypeStruct((n, mb), b.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(l, dinv, b)
