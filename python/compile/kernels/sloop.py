"""Fused S-loop reductions as a Pallas kernel.

The paper's S-loop (Listing 1.2 lines 11–15) makes three passes over the
solved block ``X̃_b``: a gemm against ``X̃_L``, a syrk per column, and a
gemv against ``ỹ``. Fusing them into one kernel reads ``X̃_b`` from HBM
once instead of three times — on a TPU the three reductions share the same
VMEM-resident column tile, and the gemm part feeds the MXU while the
column norms ride the VPU.

Gridded over SNP column tiles like the trsm kernel, so the two kernels
compose into a single per-block program with matching tiling.
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sloop_kernel(xlt_ref, yt_ref, xbt_ref, g_ref, rb_ref, d_ref):
    xb = xbt_ref[...]                       # (n, bm) — the single HBM read
    g_ref[...] = xlt_ref[...].T @ xb        # MXU: (pl, n) x (n, bm)
    rb_ref[...] = yt_ref[...] @ xb          # MXU: (1, n) x (n, bm)
    d_ref[...] = jnp.sum(xb * xb, axis=0)   # VPU reduction


@functools.partial(jax.jit, static_argnames=("bm",))
def sloop_reduce(xlt, yt, xbt, *, bm=128):
    """Compute ``(G, rb, d)`` for a solved block.

    Args:
      xlt: (n, pl) preprocessed covariates ``X̃_L``.
      yt:  (n,) preprocessed phenotype ``ỹ``.
      xbt: (n, mb) solved block ``X̃_b``. ``mb % bm == 0``.
      bm:  column tile per grid program (static).

    Returns:
      g  — (pl, mb): ``X̃_L^T X̃_b``
      rb — (mb,):   ``X̃_b^T ỹ``
      d  — (mb,):   per-column squared norms.
    """
    n, mb = xbt.shape
    pl_ = xlt.shape[1]
    if mb % bm != 0:
        raise ValueError(f"mb={mb} not a multiple of bm={bm}")
    grid = (mb // bm,)
    return pl.pallas_call(
        _sloop_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, pl_), lambda i: (0, 0)),  # X̃_L: full, shared
            pl.BlockSpec((n,), lambda i: (0,)),        # ỹ: full, shared
            pl.BlockSpec((n, bm), lambda i: (0, i)),   # X̃_b: one tile
        ],
        out_specs=[
            pl.BlockSpec((pl_, bm), lambda i: (0, i)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pl_, mb), xbt.dtype),
            jax.ShapeDtypeStruct((mb,), xbt.dtype),
            jax.ShapeDtypeStruct((mb,), xbt.dtype),
        ],
        interpret=True,
    )(xlt, yt, xbt)
