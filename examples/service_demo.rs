//! Service demo: the `cugwas serve` acceptance scenario, driven through
//! the library API — three queued jobs, two sharing one dataset, one
//! worker pair, and the shared block cache turning the second pass over
//! the shared dataset into pure RAM reads.
//!
//! ```bash
//! cargo run --release --example service_demo
//! ```
//!
//! The equivalent CLI session (what the example also writes for you to
//! replay) is:
//!
//! ```bash
//! cugwas gen-data --dir /tmp/cugwas_service_demo/s1 --n 256 --m 4096
//! cugwas gen-data --dir /tmp/cugwas_service_demo/s2 --n 256 --m 2048
//! cugwas serve --config /tmp/cugwas_service_demo/service.toml
//! ```

use cugwas::config::ServiceConfig;
use cugwas::gwas::problem::Dims;
use cugwas::service::serve;
use cugwas::storage::generate;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let root = std::env::temp_dir().join("cugwas_service_demo");
    let _ = std::fs::remove_dir_all(&root);
    let s1 = root.join("s1");
    let s2 = root.join("s2");
    println!("generating two synthetic studies under {} …", root.display());
    generate(&s1, Dims::new(256, 3, 4096)?, 256, 42)?;
    generate(&s2, Dims::new(256, 3, 2048)?, 256, 43)?;

    // The same config `cugwas serve --config …` would load: alpha and
    // gamma share dataset s1 — alpha (higher priority) streams it from
    // disk, gamma then streams it from the shared cache.
    let toml = format!(
        r#"[service]
workers = 2
mem_budget_mb = 1024
cache_mb = 128

[job.alpha]
dataset = "{s1}"
block = 256
priority = 2

[job.beta]
dataset = "{s2}"
block = 256

[job.gamma]
dataset = "{s1}"
block = 256
"#,
        s1 = s1.display(),
        s2 = s2.display(),
    );
    let config_path = root.join("service.toml");
    std::fs::write(&config_path, &toml)?;
    println!("service config written to {} — replayable via:", config_path.display());
    println!("  cugwas serve --config {}\n", config_path.display());

    let report = serve(&ServiceConfig::from_toml(&toml)?)?;
    print!("{}", report.render());
    assert_eq!(report.failed(), 0, "all three jobs must complete");
    assert!(
        report.cache.hits > 0,
        "the second pass over the shared dataset must hit the cache"
    );
    std::fs::remove_dir_all(&root)?;
    Ok(())
}
