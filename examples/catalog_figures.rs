//! Fig. 1 — GWAS catalog statistics (SNP counts and sample sizes per
//! publication year, medians with quartile bars).
//!
//! ```bash
//! cargo run --release --example catalog_figures
//! ```
//!
//! Prints the two panels as data tables plus a terminal sparkline of the
//! medians. The catalog itself is synthesized (DESIGN.md §4) with the
//! growth shape reported in the paper's §1.2.

use cugwas::stats::{summarize_by_year, synthesize_catalog};

fn main() {
    let rows = synthesize_catalog(2013);
    let summaries = summarize_by_year(&rows);

    println!("Fig. 1a — SNP count per study (median, Q1–Q3)");
    println!("{:<6}{:>9}{:>14}{:>14}{:>14}", "year", "studies", "q1", "median", "q3");
    for s in &summaries {
        println!(
            "{:<6}{:>9}{:>14.0}{:>14.0}{:>14.0}",
            s.year, s.studies, s.snp_count.q1, s.snp_count.median, s.snp_count.q3
        );
    }
    sparkline("snp-count medians", summaries.iter().map(|s| s.snp_count.median).collect());

    println!("\nFig. 1b — sample size per study (median, Q1–Q3)");
    println!("{:<6}{:>9}{:>12}{:>12}{:>12}", "year", "studies", "q1", "median", "q3");
    for s in &summaries {
        println!(
            "{:<6}{:>9}{:>12.0}{:>12.0}{:>12.0}",
            s.year, s.studies, s.sample_size.q1, s.sample_size.median, s.sample_size.q3
        );
    }
    sparkline("sample-size medians", summaries.iter().map(|s| s.sample_size.median).collect());

    println!(
        "\npaper's reading: SNP counts explode after 2009 while sample sizes plateau\n\
         around 10 000 — hence an algorithm that scales in m at fixed n (§1.2)."
    );
}

fn sparkline(label: &str, values: Vec<f64>) {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().cloned().fold(f64::MIN, f64::max);
    let min = values.iter().cloned().fold(f64::MAX, f64::min);
    let span = (max - min).max(1e-9);
    let line: String = values
        .iter()
        .map(|v| BARS[(((v - min) / span) * 7.0).round() as usize])
        .collect();
    println!("  {label}: {line}");
}
