//! End-to-end validation driver (the repo's headline example).
//!
//! Runs a realistic small study — disk-resident genotypes streamed
//! through the full three-layer stack — with ALL FOUR solvers, verifies
//! every one against the in-core oracle, and reports the comparative
//! table the paper's evaluation is built around. This is the run recorded
//! in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_study
//! ```
//!
//! Falls back to the native backend (with a notice) if artifacts are
//! missing. The study: n=512 samples, m=16384 SNPs (64 MiB of X_R),
//! streamed in 256-column blocks — big enough that warmup/steady/drain
//! phases are all exercised, small enough to verify against the oracle.

use cugwas::baselines::{run_naive, run_ooc_cpu, run_probabel};
use cugwas::bench::{ratio_cell, Table};
use cugwas::coordinator::{run, verify_against_oracle, BackendKind, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::generate;
use cugwas::util::{human_bytes, human_duration};
use std::time::Duration;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let artifacts = cugwas::runtime::default_artifacts_dir();
    let have_artifacts = artifacts.join("manifest.tsv").exists();
    let backend = if have_artifacts {
        BackendKind::Pjrt { artifacts }
    } else {
        eprintln!("note: no artifacts found — using the native backend (run `make artifacts`)");
        BackendKind::Native
    };

    let dir = std::env::temp_dir().join("cugwas_full_study");
    let _ = std::fs::remove_dir_all(&dir);
    let dims = Dims::new(512, 3, 16_384)?;
    println!(
        "study: n={}, p={}, m={} — X_R = {} on disk",
        dims.n,
        dims.p(),
        dims.m,
        human_bytes(dims.xr_bytes())
    );
    generate(&dir, dims, 256, 2013)?;

    let block = 256;
    let mut rows: Vec<(String, f64)> = Vec::new();

    // cuGWAS (the paper's contribution), 1 lane.
    let mut cfg = PipelineConfig::new(&dir, block);
    cfg.backend = backend.clone();
    let cu = run(&cfg)?;
    let d = verify_against_oracle(&dir, 1e-6)?;
    println!("cuGWAS (1 lane):        {} [max|Δ| {d:.1e}]", fmt(cu.wall_secs));
    rows.push(("cuGWAS (1 lane)".into(), cu.wall_secs));

    // cuGWAS, 2 lanes — the block scales with lane count (paper §3.2),
    // so each lane keeps the same artifact shape (mb = 256).
    let mut cfg2 = cfg.clone();
    cfg2.block = 2 * block;
    cfg2.ngpus = 2;
    let cu2 = run(&cfg2)?;
    let d = verify_against_oracle(&dir, 1e-6)?;
    println!("cuGWAS (2 lanes):       {} [max|Δ| {d:.1e}]", fmt(cu2.wall_secs));
    rows.push(("cuGWAS (2 lanes)".into(), cu2.wall_secs));

    // OOC-HP-GWAS (Listing 1.2).
    let ooc = run_ooc_cpu(&dir, block, None)?;
    let d = verify_against_oracle(&dir, 1e-6)?;
    println!("OOC-HP-GWAS (CPU):      {} [max|Δ| {d:.1e}]", fmt(ooc.wall_secs));
    rows.push(("OOC-HP-GWAS (CPU)".into(), ooc.wall_secs));

    // Naive offload (Fig. 3 pattern).
    let naive = run_naive(&dir, block, &backend, None)?;
    let d = verify_against_oracle(&dir, 1e-6)?;
    println!("naive offload:          {} [max|Δ| {d:.1e}]", fmt(naive.wall_secs));
    rows.push(("naive offload".into(), naive.wall_secs));

    // ProbABEL-like per-SNP (the 488× comparator).
    let pa = run_probabel(&dir)?;
    let d = verify_against_oracle(&dir, 1e-5)?;
    println!("ProbABEL-like per-SNP:  {} [max|Δ| {d:.1e}]", fmt(pa.wall_secs));
    rows.push(("ProbABEL-like".into(), pa.wall_secs));

    // Comparative table (speedups relative to cuGWAS 1-lane).
    let mut table = Table::new(
        "full_study — all solvers, verified, same dataset",
        &["solver", "wall", "SNPs/s", "vs cuGWAS"],
    );
    let base = rows[0].1;
    for (name, wall) in &rows {
        table.row(&[
            name.clone(),
            fmt(*wall),
            format!("{:.0}", dims.m as f64 / wall),
            ratio_cell(*wall, base),
        ]);
    }
    table.print();

    println!("\npipeline phase breakdown (cuGWAS, 1 lane):");
    print!("{}", cu.metrics.table(Duration::from_secs_f64(cu.wall_secs)));
    std::fs::remove_dir_all(&dir)?;
    Ok(())
}

fn fmt(secs: f64) -> String {
    human_duration(Duration::from_secs_f64(secs))
}
