//! Multi-GPU scaling — the live counterpart of Fig. 6b.
//!
//! Streams the same study through 1, 2, 3 and 4 device lanes and reports
//! scaling. On this CPU-only testbed the lanes share cores, so the
//! *paper-scale* scaling claim (×1.9 per doubling) is reproduced by the
//! DES instead (printed alongside); what the live run demonstrates is the
//! coordinator's lane fan-out, split/merge correctness and overlap.
//!
//! ```bash
//! cargo run --release --example multi_gpu
//! ```

use cugwas::bench::{ratio_cell, Table};
use cugwas::coordinator::{run, verify_against_oracle, PipelineConfig};
use cugwas::devsim::{simulate, Algo, HardwareProfile, SimConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::generate;
use cugwas::util::human_duration;
use std::time::Duration;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::env::temp_dir().join("cugwas_multi_gpu");
    let _ = std::fs::remove_dir_all(&dir);
    let dims = Dims::new(256, 3, 8_192)?;
    generate(&dir, dims, 256, 7)?;

    let mut table = Table::new(
        "live lanes (this machine) + DES at paper scale (Tesla S2050)",
        &["lanes", "live wall", "live vs 1", "sim (n=10k, m=100k)", "sim vs 1"],
    );
    let mut live_base = 0.0;
    let mut sim_base = 0.0;
    for lanes in [1usize, 2, 3, 4] {
        // Live run: block scales with lane count, like the paper (§3.2).
        let mut cfg = PipelineConfig::new(&dir, 128 * lanes);
        cfg.ngpus = lanes;
        let rep = run(&cfg)?;
        verify_against_oracle(&dir, 1e-6)?;
        // Paper-scale DES on the Tesla profile (Fig. 6b's machine).
        let sim = simulate(
            Algo::CuGwas,
            &SimConfig {
                dims: Dims::new(10_000, 3, 100_000)?,
                block: 5_000 * lanes,
                ngpus: lanes,
                host_buffers: 3,
                profile: HardwareProfile::tesla(),
            },
        )?;
        if lanes == 1 {
            live_base = rep.wall_secs;
            sim_base = sim.total_secs;
        }
        table.row(&[
            lanes.to_string(),
            human_duration(Duration::from_secs_f64(rep.wall_secs)),
            ratio_cell(live_base, rep.wall_secs),
            human_duration(Duration::from_secs_f64(sim.total_secs)),
            ratio_cell(sim_base, sim.total_secs),
        ]);
    }
    table.print();
    println!("\npaper claim: ×1.9 per GPU doubling (Fig. 6b) — compare the 'sim vs 1' column.");
    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
