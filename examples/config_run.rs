//! Config-file driven run — the deployment-style entry point.
//!
//! Reads a TOML run configuration (dataset geometry + pipeline topology +
//! simulation profile), generates the dataset if absent, streams it, and
//! cross-checks the live topology against the DES prediction for the
//! same configuration at paper scale.
//!
//! ```bash
//! cargo run --release --example config_run [path/to/run.toml]
//! ```

use cugwas::config::RunConfig;
use cugwas::coordinator::{run, verify_against_oracle};
use cugwas::devsim::{simulate, Algo, SimConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::generate;
use cugwas::util::human_duration;
use std::time::Duration;

const DEFAULT_CONFIG: &str = r#"
# cuGWAS run configuration (see rust/src/config/schema.rs for all keys)
[dataset]
dir = "/tmp/cugwas_config_run"
n = 256
pl = 3
m = 4096
seed = 7

[pipeline]
block = 256
ngpus = 2
host_buffers = 3
mode = "trsm"
backend = "native"

[sim]
profile = "tesla"
"#;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = match std::env::args().nth(1) {
        Some(path) => RunConfig::load(std::path::Path::new(&path))?,
        None => {
            println!("(no config path given — using the built-in example config)\n{DEFAULT_CONFIG}");
            RunConfig::from_toml(DEFAULT_CONFIG)?
        }
    };

    if !cfg.dataset_dir.join("meta.txt").exists() {
        println!("generating dataset at {} …", cfg.dataset_dir.display());
        generate(&cfg.dataset_dir, cfg.dims, cfg.gen_block, cfg.seed)?;
    }

    let report = run(&cfg.pipeline)?;
    println!(
        "live: {} SNPs in {} ({:.0} SNPs/s, {} lanes)",
        report.snps,
        human_duration(Duration::from_secs_f64(report.wall_secs)),
        report.snps_per_sec,
        cfg.pipeline.ngpus
    );
    verify_against_oracle(&cfg.dataset_dir, 1e-6)?;
    println!("verified against the in-core oracle.");

    // Same topology at paper scale through the DES.
    let sim = simulate(
        Algo::CuGwas,
        &SimConfig {
            dims: Dims::new(10_000, cfg.dims.pl, 100_000)?,
            block: 5_000 * cfg.pipeline.ngpus,
            ngpus: cfg.pipeline.ngpus,
            host_buffers: cfg.pipeline.host_buffers,
            profile: cfg.sim.profile,
        },
    )?;
    println!(
        "same topology at paper scale ({}): {} for m=100k — gpu util {:.0}%",
        cfg.sim.profile.name,
        human_duration(Duration::from_secs_f64(sim.total_secs)),
        sim.gpu_util * 100.0
    );
    Ok(())
}
