//! Quickstart: synthesize a small study, stream it through the cuGWAS
//! pipeline, and verify the results against the in-core oracle.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the native backend so it works before `make artifacts`; pass
//! `--pjrt` to exercise the AOT path (requires artifacts for n=512).

use cugwas::coordinator::{run, verify_against_oracle, BackendKind, PipelineConfig};
use cugwas::gwas::problem::Dims;
use cugwas::storage::generate;
use cugwas::util::human_duration;
use std::time::Duration;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let dir = std::env::temp_dir().join("cugwas_quickstart");
    let _ = std::fs::remove_dir_all(&dir);

    // A small study: 512 individuals, 3 covariates + 1 SNP, 2048 SNPs.
    let dims = Dims::new(512, 3, 2048)?;
    println!("generating synthetic study at {} …", dir.display());
    generate(&dir, dims, 256, 42)?;

    // Stream it: 256 SNPs per pipeline iteration, 1 device lane,
    // 3 host buffers (the paper's configuration).
    let mut cfg = PipelineConfig::new(&dir, 256);
    if use_pjrt {
        cfg.backend = BackendKind::Pjrt { artifacts: "artifacts".into() };
        println!("backend: PJRT (AOT HLO artifacts)");
    } else {
        println!("backend: native (pass --pjrt for the AOT path)");
    }
    let report = run(&cfg)?;
    println!(
        "solved {} GLS problems in {} blocks over {} ({:.0} SNPs/s)",
        report.snps,
        report.blocks,
        human_duration(Duration::from_secs_f64(report.wall_secs)),
        report.snps_per_sec
    );
    print!("{}", report.metrics.table(Duration::from_secs_f64(report.wall_secs)));

    // Check every r_i against the dense in-core reference (Listing 1.1).
    let diff = verify_against_oracle(&dir, 1e-7)?;
    println!("verified against in-core oracle: max |Δ| = {diff:.2e}");
    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
