#!/usr/bin/env python3
"""Perf-trajectory trend renderer + soft regression gate.

CI's ``bench-smoke`` job uploads one ``BENCH_<short-sha>.json`` artifact
per push (one JSON object per line, each with a ``bench`` field).  This
script renders the accumulated artifacts as a markdown table — one row
per push, one column per headline metric — and gates the build on the
headline streaming throughput: the job fails when the current value
drops more than ``GATE_DROP`` below the median of the recent history.

Usage::

    bench_trend.py CURRENT.json [HISTORY_DIR]

``HISTORY_DIR`` holds previously downloaded ``BENCH_*.json`` files
(oldest first by mtime).  With no history the gate passes trivially —
the first push on a fresh repo must not fail itself.

Exit status: 0 = ok (or no history), 1 = regression beyond the gate.
"""

import json
import os
import sys

# The gated metrics: live streaming throughput of the pipelined solver,
# the cache-hit serving throughput of the zero-copy block plane, the
# multi-trait batching rate (SNP·trait solves/s at the wide batch width),
# and the register-tiled microkernel's headline gemm/trsm GFlop/s.
GATES = [
    ("headline_table", "live_cugwas_snps_per_sec"),
    ("service_throughput", "cache_hit_snps_per_sec"),
    ("service_throughput", "batched_snps_x_traits_per_sec"),
    ("linalg_micro", "gemm_gflops"),
    ("linalg_micro", "trsm_gflops"),
]
# Soft gate: fail only on a >20% drop vs. the recent median (medians
# absorb one noisy CI runner; a hard cliff still fails loudly).
GATE_DROP = 0.20
# Columns of the trend table, as (bench, key) pairs.
COLUMNS = [
    ("headline_table", "live_cugwas"),
    ("headline_table", "live_cugwas_snps_per_sec"),
    ("service_throughput", "cache_hit_snps_per_sec"),
    ("service_throughput", "shared_cache_speedup"),
    ("service_throughput", "batched_snps_x_traits_per_sec"),
    ("linalg_micro", "gemm_gflops"),
    ("linalg_micro", "trsm_gflops"),
    ("linalg_micro", "gemm_micro_speedup"),
    ("headline_table", "cugwas1_vs_ooc"),
    ("headline_table", "cugwas4_vs_ooc"),
]


def load(path):
    """Parse one BENCH_*.json file into {(bench, key): value}."""
    out = {}
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            bench = rec.get("bench")
            key = rec.get("row") or ":".join(
                str(rec[k])
                for k in ("kernel", "shape", "threads", "case", "config")
                if k in rec
            )
            val = next(
                (rec[f] for f in ("value", "gflops", "wall_secs", "median_secs") if f in rec),
                None,
            )
            if bench and key and isinstance(val, (int, float)):
                out[(bench, key)] = float(val)
    return out


def sha_of(path):
    name = os.path.basename(path)
    return name[len("BENCH_"):-len(".json")] if name.startswith("BENCH_") else name


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 2
    current_path = argv[1]
    history_dir = argv[2] if len(argv) > 2 else None
    history = []
    if history_dir and os.path.isdir(history_dir):
        files = [
            os.path.join(history_dir, f)
            for f in os.listdir(history_dir)
            if f.startswith("BENCH_") and f.endswith(".json")
        ]
        files.sort(key=os.path.getmtime)
        cur_name = os.path.basename(current_path)
        history = [(sha_of(f), load(f)) for f in files if os.path.basename(f) != cur_name]
    current = (sha_of(current_path) + " (this push)", load(current_path))

    # ---- trend table ----------------------------------------------------
    print("### perf trajectory")
    print()
    header = ["push"] + [f"{b}:{k}" for b, k in COLUMNS]
    print("| " + " | ".join(header) + " |")
    print("|" + "---|" * len(header))
    for sha, metrics in history + [current]:
        cells = [sha]
        for col in COLUMNS:
            v = metrics.get(col)
            cells.append(f"{v:.4g}" if v is not None else "—")
        print("| " + " | ".join(cells) + " |")
    print()

    # ---- regression gates -----------------------------------------------
    status = 0
    for gate_bench, gate_row in GATES:
        cur_val = current[1].get((gate_bench, gate_row))
        past = [m.get((gate_bench, gate_row)) for _, m in history]
        past = [v for v in past if v is not None]
        if not past:
            # A fresh repo — or a headline that first appears in this
            # push — has no history for this series. A new series has no
            # baseline to regress from, so the gate is skipped even if
            # the current value is missing; it starts being enforced on
            # the next push, once today's value is in the history.
            print(f"gate: {gate_row} — new series (no baseline), gate skipped")
            continue
        if cur_val is None:
            print(f"gate: {gate_row} missing from the current run — failing")
            status = 1
            continue
        baseline = sorted(past)[len(past) // 2]
        floor = baseline * (1.0 - GATE_DROP)
        verdict = "OK" if cur_val >= floor else "REGRESSION"
        print(
            f"gate: {gate_row} = {cur_val:.1f} vs median-of-{len(past)} baseline "
            f"{baseline:.1f} (floor {floor:.1f}) → {verdict}"
        )
        if cur_val < floor:
            status = 1
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv))
